//! Functional sharded feature cache for the serving hot path.
//!
//! Unlike the statistics-only cache models in [`crate::cachesim`], this
//! cache really stores feature rows: a hit copies the row out of the
//! cache slab instead of reading the (large, cold) feature table. The
//! set-associative true-LRU bookkeeping is the same
//! [`SetAssocCore`](crate::cachesim::SetAssocCore) that backs the L2
//! model — promoted here from simulator to data structure by attaching
//! a payload slab indexed by the core's slot ids.
//!
//! Sharding: node id → shard (round-robin by id, so community-ordered
//! ids spread evenly), one mutex per shard, `Arc`-shareable across the
//! worker pool. Hit/miss counters live with each shard and aggregate
//! into [`CacheStats`].

use std::sync::Mutex;

use crate::cachesim::SetAssocCore;

/// Geometry of one [`ShardedFeatureCache`].
#[derive(Clone, Debug)]
pub struct FeatureCacheConfig {
    /// Total feature rows cached across all shards.
    pub rows: usize,
    /// Mutex-striped shards within the cache (concurrency, not device
    /// shards).
    pub shards: usize,
    /// Associativity within a shard (clamped to the shard's rows; a
    /// shard with `ways == rows` is fully associative = exact LRU).
    pub ways: usize,
    /// Floats per cached feature row.
    pub feat_dim: usize,
}

impl FeatureCacheConfig {
    /// Serving default: cache ~1/8 of the table in 8 shards, 8-way.
    pub fn for_dataset(n: usize, feat_dim: usize) -> FeatureCacheConfig {
        FeatureCacheConfig {
            rows: (n / 8).max(64),
            shards: 8,
            ways: 8,
            feat_dim,
        }
    }
}

struct Shard {
    core: SetAssocCore,
    /// `slots * feat_dim` payload, indexed by the core's slot ids.
    slab: Vec<f32>,
    hits: u64,
    misses: u64,
}

/// Aggregated hit/miss counters.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct CacheStats {
    /// Fetches served from the cache slab.
    pub hits: u64,
    /// Fetches that fell through to the feature table.
    pub misses: u64,
}

impl CacheStats {
    /// hits / (hits + misses); 0 when nothing was fetched.
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

/// Mutex-striped set-associative feature-row cache (see module docs).
pub struct ShardedFeatureCache {
    shards: Vec<Mutex<Shard>>,
    feat_dim: usize,
}

impl ShardedFeatureCache {
    /// Geometry is rounded *up* to whole sets, so the effective
    /// capacity is ≥ `cfg.rows` (never silently below the knob);
    /// [`ShardedFeatureCache::rows`] reports the exact figure.
    pub fn new(cfg: &FeatureCacheConfig) -> ShardedFeatureCache {
        let n_shards = cfg.shards.max(1);
        let rows_per_shard = cfg.rows.div_ceil(n_shards).max(1);
        let ways = cfg.ways.clamp(1, rows_per_shard);
        let sets = rows_per_shard.div_ceil(ways);
        let shards = (0..n_shards)
            .map(|_| {
                let core = SetAssocCore::new(sets, ways);
                let slab = vec![0f32; core.slots() * cfg.feat_dim];
                Mutex::new(Shard { core, slab, hits: 0, misses: 0 })
            })
            .collect();
        ShardedFeatureCache { shards, feat_dim: cfg.feat_dim }
    }

    /// Floats per cached row.
    pub fn feat_dim(&self) -> usize {
        self.feat_dim
    }

    /// Effective total capacity in feature rows (all shards).
    pub fn rows(&self) -> usize {
        self.shards.len() * self.shards[0].lock().unwrap().core.slots()
    }

    /// Mutex-striped shard count.
    pub fn num_shards(&self) -> usize {
        self.shards.len()
    }

    #[inline]
    fn shard_of(&self, node: u32) -> usize {
        node as usize % self.shards.len()
    }

    /// Fetch `node`'s feature row into `dst`: on a hit the row comes
    /// from the cache slab (the feature-table read is skipped); on a
    /// miss `src` (the table row) is installed and copied through.
    /// Returns whether it hit.
    pub fn fetch(&self, node: u32, src: &[f32], dst: &mut [f32]) -> bool {
        let f = self.feat_dim;
        debug_assert_eq!(src.len(), f);
        debug_assert_eq!(dst.len(), f);
        let mut sh = self.shards[self.shard_of(node)].lock().unwrap();
        let p = sh.core.probe(node as u64);
        let off = p.slot * f;
        if p.hit {
            sh.hits += 1;
            dst.copy_from_slice(&sh.slab[off..off + f]);
            true
        } else {
            sh.misses += 1;
            sh.slab[off..off + f].copy_from_slice(src);
            dst.copy_from_slice(src);
            false
        }
    }

    /// Aggregate hit/miss counters over all shards.
    pub fn stats(&self) -> CacheStats {
        let mut s = CacheStats::default();
        for sh in &self.shards {
            let g = sh.lock().unwrap();
            s.hits += g.hits;
            s.misses += g.misses;
        }
        s
    }

    /// Zero the hit/miss counters (contents stay cached).
    pub fn reset_counters(&self) {
        for sh in &self.shards {
            let mut g = sh.lock().unwrap();
            g.hits = 0;
            g.misses = 0;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cachesim::SoftwareCache;
    use crate::util::rng::Rng;

    fn table(n: usize, f: usize) -> Vec<f32> {
        (0..n * f).map(|i| i as f32).collect()
    }

    fn row(t: &[f32], v: u32, f: usize) -> &[f32] {
        &t[v as usize * f..(v as usize + 1) * f]
    }

    #[test]
    fn hit_returns_cached_row_contents() {
        let f = 8;
        let t = table(100, f);
        let cache = ShardedFeatureCache::new(&FeatureCacheConfig {
            rows: 32,
            shards: 4,
            ways: 8,
            feat_dim: f,
        });
        let mut dst = vec![0f32; f];
        assert!(!cache.fetch(5, row(&t, 5, f), &mut dst));
        assert_eq!(dst, row(&t, 5, f));
        let mut dst2 = vec![0f32; f];
        assert!(cache.fetch(5, row(&t, 5, f), &mut dst2));
        assert_eq!(dst2, row(&t, 5, f));
        let s = cache.stats();
        assert_eq!((s.hits, s.misses), (1, 1));
    }

    /// Acceptance check: with fully-associative shards, hit/miss
    /// accounting matches a reference single-shard exact-LRU
    /// ([`SoftwareCache`]) replayed per shard, request by request.
    #[test]
    fn sharded_accounting_matches_reference_lru() {
        let f = 4;
        let n = 500usize;
        let shards = 4usize;
        let rows_per_shard = 16usize;
        let t = table(n, f);
        let cache = ShardedFeatureCache::new(&FeatureCacheConfig {
            rows: shards * rows_per_shard,
            shards,
            ways: rows_per_shard, // fully associative per shard
            feat_dim: f,
        });
        let mut reference: Vec<SoftwareCache> = (0..shards)
            .map(|_| SoftwareCache::new(rows_per_shard, n))
            .collect();
        let mut rng = Rng::new(42);
        let mut dst = vec![0f32; f];
        for step in 0..20_000 {
            // skewed stream with locality bursts
            let v = if step % 3 == 0 {
                rng.usize_below(32) as u32
            } else {
                rng.usize_below(n) as u32
            };
            let want = reference[v as usize % shards].access(v);
            let got = cache.fetch(v, row(&t, v, f), &mut dst);
            assert_eq!(got, want, "step {step} node {v}");
            assert_eq!(dst, row(&t, v, f), "payload corrupt at node {v}");
        }
        let s = cache.stats();
        let ref_hits: u64 = reference.iter().map(|c| c.hits).sum();
        let ref_misses: u64 = reference.iter().map(|c| c.misses).sum();
        assert_eq!((s.hits, s.misses), (ref_hits, ref_misses));
        assert!(s.hits > 0 && s.misses > 0);
    }

    #[test]
    fn concurrent_fetches_are_consistent() {
        let f = 8;
        let n = 256usize;
        let t = table(n, f);
        let cache = ShardedFeatureCache::new(&FeatureCacheConfig {
            rows: 64,
            shards: 8,
            ways: 8,
            feat_dim: f,
        });
        std::thread::scope(|s| {
            for tid in 0..4u64 {
                let cache = &cache;
                let t = &t;
                s.spawn(move || {
                    let mut rng = Rng::new(tid);
                    let mut dst = vec![0f32; f];
                    for _ in 0..5_000 {
                        let v = rng.usize_below(n) as u32;
                        cache.fetch(v, row(t, v, f), &mut dst);
                        assert_eq!(dst, row(t, v, f));
                    }
                });
            }
        });
        let s = cache.stats();
        assert_eq!(s.hits + s.misses, 20_000);
    }

    #[test]
    fn capacity_rounds_up_not_down() {
        // 100 rows over 8 shards doesn't divide evenly; geometry must
        // never deliver less capacity than the knob requested
        let c = ShardedFeatureCache::new(&FeatureCacheConfig {
            rows: 100,
            shards: 8,
            ways: 8,
            feat_dim: 2,
        });
        assert!(c.rows() >= 100, "effective {} < requested 100", c.rows());
    }

    #[test]
    fn reset_counters_clears_stats() {
        let f = 2;
        let t = table(10, f);
        let cache = ShardedFeatureCache::new(&FeatureCacheConfig {
            rows: 8,
            shards: 2,
            ways: 4,
            feat_dim: f,
        });
        let mut dst = vec![0f32; f];
        cache.fetch(1, row(&t, 1, f), &mut dst);
        cache.reset_counters();
        assert_eq!(cache.stats(), CacheStats::default());
    }
}
