//! Load generation: Zipf-skewed request traces replayed against the
//! serving queue, in either of two arrival disciplines
//! ([`Arrival`]):
//!
//! * **Closed loop** — N client threads each block on their reply
//!   before issuing the next request, so offered load adapts to server
//!   capacity. Good for measuring peak throughput; structurally unable
//!   to show the latency cliff, because an overloaded server simply
//!   slows its own clients down.
//! * **Open loop** — requests arrive as a Poisson process at a fixed
//!   offered rate (exponential inter-arrival times), independent of
//!   completions. Past the saturation rate the backlog grows without
//!   bound, which is exactly the regime [`super::admission`] exists to
//!   protect; sweeping the rate maps out the latency cliff.
//!
//! Both paths run every arriving request through the admission
//! controller at enqueue time (the open loop atomically, via
//! [`RequestQueue::push_gated`]); a full queue in the open loop is a
//! drop-tail shed rather than backpressure, since blocking would turn
//! the open loop closed.
//!
//! Popularity is assigned by a seeded random permutation (rank →
//! node), so hot nodes scatter across communities instead of
//! clustering in the low ids the community reordering produces —
//! community locality must then be *recovered* by the batcher's knob,
//! which is exactly what the benchmark measures.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc;
use std::sync::Mutex;
use std::time::Duration;

use anyhow::{bail, Context, Result};

use crate::obs::{EventKind, Recorder, TRACK_CLIENT};
use crate::runtime::host::top1;
use crate::util::rng::Rng;

use super::admission::{AdmissionController, AdmissionPolicy, AdmitDecision};
use super::queue::{PushRejected, RequestQueue};
use super::shard::LabelCell;
use super::{Reply, Request, ServeClock};

/// Arrival discipline of the load generator.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Arrival {
    /// Each client blocks on its reply before issuing the next request.
    Closed,
    /// Open-loop Poisson arrivals at a fixed aggregate offered rate
    /// (requests per second), split evenly across client threads.
    Poisson {
        /// Aggregate offered load in requests per second.
        rate_rps: f64,
    },
}

impl Arrival {
    /// Parse the CLI knob: `closed` or `poisson:RATE` (RATE in req/s).
    pub fn parse(s: &str) -> Result<Arrival> {
        if s == "closed" {
            return Ok(Arrival::Closed);
        }
        if let Some(r) = s.strip_prefix("poisson:") {
            let rate: f64 =
                r.parse().with_context(|| format!("bad arrival rate {r:?}"))?;
            if !(rate.is_finite() && rate > 0.0) {
                bail!("arrival rate must be a positive number, got {r}");
            }
            return Ok(Arrival::Poisson { rate_rps: rate });
        }
        bail!("unknown arrival {s:?} (try: closed | poisson:RATE)")
    }

    /// Human/JSON label (`closed` / `poisson:RATE`).
    pub fn label(&self) -> String {
        match self {
            Arrival::Closed => "closed".to_string(),
            Arrival::Poisson { rate_rps } => format!("poisson:{rate_rps}"),
        }
    }

    /// Offered rate in req/s, when the discipline fixes one.
    pub fn offered_rps(&self) -> Option<f64> {
        match self {
            Arrival::Closed => None,
            Arrival::Poisson { rate_rps } => Some(*rate_rps),
        }
    }
}

/// Load-generator configuration.
#[derive(Clone, Debug)]
pub struct LoadConfig {
    /// Client threads issuing requests.
    pub clients: usize,
    /// Requests each client issues before exiting.
    pub requests_per_client: usize,
    /// Zipf exponent (1.0–1.3 is typical web skew; 0 = uniform).
    pub zipf_s: f64,
    /// Arrival discipline (closed loop or open-loop Poisson).
    pub arrival: Arrival,
    /// Trace seed (node popularity + per-client request streams).
    pub seed: u64,
}

/// Per-request record collected by the clients / reply collector.
/// Shed requests never produce a record — they are counted by the
/// [`AdmissionController`] instead. Latency is measured enqueue →
/// batch completion (`Reply::finish_us`) in both arrival modes, so
/// closed- and open-loop reports are directly comparable.
#[derive(Clone, Copy, Debug)]
pub struct ReqRecord {
    /// Enqueue → batch-completion latency, µs.
    pub latency_us: u64,
    /// The reply landed after the request's deadline.
    pub deadline_missed: bool,
    /// The reply carried an executor error (its latency is excluded
    /// from the report's percentiles).
    pub error: bool,
    /// The reply carried logits, so it counts toward accuracy (false
    /// for error replies and for the no-op executor's empty logits).
    pub evaluated: bool,
    /// Top-1 prediction matched the request's ground-truth label
    /// (only meaningful when `evaluated`).
    pub correct: bool,
}

/// Score one reply for the accuracy columns: a reply is `evaluated`
/// when it carries logits and no error, and `correct` when the argmax
/// matches the ground-truth label the request carried through.
fn score_reply(rep: &Reply) -> (bool, bool) {
    let evaluated = !rep.error && !rep.logits.is_empty();
    let correct = evaluated && top1(&rep.logits) == rep.label as usize;
    (evaluated, correct)
}

/// Everything a load-generator thread needs, shared by reference
/// across all clients of a run.
pub struct ClientCtx<'a> {
    /// The serving queue requests are pushed onto.
    pub queue: &'a RequestQueue<Request>,
    /// The run's shared monotonic clock.
    pub clock: &'a ServeClock,
    /// Load shape (client count, per-client quota, skew, arrival).
    pub lcfg: &'a LoadConfig,
    /// Per-request deadline budget (µs from arrival).
    pub deadline_us: u64,
    /// Rank → node popularity permutation ([`popularity_perm`]).
    pub perm: &'a [u32],
    /// Ground-truth labels (node id → label), attached to every
    /// request so accuracy is scored on real labels.
    pub labels: &'a [u16],
    /// Shared Zipf sampler over popularity ranks.
    pub zipf: &'a ZipfSampler,
    /// Sink for completion records.
    pub records: &'a Mutex<Vec<ReqRecord>>,
    /// Admission gate consulted at enqueue time.
    pub adm: &'a AdmissionController,
    /// Current community-label snapshot cell (labels + shard plan),
    /// read per request so admission attribution follows live
    /// relabels.
    pub label_cell: &'a LabelCell,
    /// Per-shard queued-batch depth counters (routing backlog).
    pub depths: &'a [AtomicUsize],
    /// Trace recorder ([`Recorder::disabled`] when tracing is off).
    /// Clients emit `Enqueue` / `Degrade` / `Shed` instants for
    /// trace-sampled request ids on the client track.
    pub rec: &'a Recorder,
}

impl ClientCtx<'_> {
    /// Sample the next request's target node for `rng`.
    fn sample_node(&self, rng: &mut Rng) -> u32 {
        self.perm[self.zipf.sample(rng)]
    }

    /// The shard that would own a request for `node`, and its current
    /// routed-batch backlog (admission inputs).
    fn shard_and_depth(&self, node: u32) -> (usize, usize) {
        let shard = self.label_cell.snapshot().owner_shard(node);
        (shard, self.depths[shard].load(Ordering::Relaxed))
    }
}

/// Rank → node popularity mapping (seeded shuffle of all node ids).
pub fn popularity_perm(n: usize, seed: u64) -> Vec<u32> {
    let mut perm: Vec<u32> = (0..n as u32).collect();
    let mut rng = Rng::new(seed ^ 0x21F0_5EED);
    rng.shuffle(&mut perm);
    perm
}

/// Zipf(rank) sampler over `0..n` via a precomputed CDF + binary
/// search; built once and shared read-only across client threads.
pub struct ZipfSampler {
    cdf: Vec<f64>,
}

impl ZipfSampler {
    /// Build the CDF for `n` ranks with exponent `s`.
    pub fn new(n: usize, s: f64) -> ZipfSampler {
        let n = n.max(1);
        let mut cdf = Vec::with_capacity(n);
        let mut acc = 0.0f64;
        for r in 0..n {
            acc += 1.0 / ((r + 1) as f64).powf(s);
            cdf.push(acc);
        }
        ZipfSampler { cdf }
    }

    /// Draw one rank in `0..n`.
    pub fn sample(&self, rng: &mut Rng) -> usize {
        let total = *self.cdf.last().unwrap();
        let x = rng.f64() * total;
        match self.cdf.binary_search_by(|v| v.partial_cmp(&x).unwrap()) {
            Ok(i) => i,
            Err(i) => i.min(self.cdf.len() - 1),
        }
    }
}

/// One exponential inter-arrival gap (µs) for a Poisson process at
/// `rate_rps` requests per second (inverse-CDF sampling). Rounded to
/// the nearest microsecond rather than truncated, so the realized
/// offered rate tracks the configured one even at short mean gaps.
pub fn poisson_interarrival_us(rng: &mut Rng, rate_rps: f64) -> u64 {
    let u = rng.f64(); // [0, 1) -> 1 - u in (0, 1], so ln() is finite
    let dt_s = -(1.0 - u).ln() / rate_rps.max(1e-9);
    (dt_s * 1e6).round() as u64
}

fn client_rng(lcfg: &LoadConfig, client_id: u64) -> Rng {
    Rng::new(
        lcfg.seed
            ^ (client_id.wrapping_add(1)).wrapping_mul(0xA24B_AED4_963E_E407),
    )
}

/// One closed-loop client: sample node → admission gate → enqueue →
/// block on reply → record latency → repeat. A shed request is skipped
/// (the controller counted it) and the client moves straight on.
pub fn client_loop(client_id: u64, ctx: &ClientCtx<'_>) {
    let mut rng = client_rng(ctx.lcfg, client_id);
    for k in 0..ctx.lcfg.requests_per_client {
        let node = ctx.sample_node(&mut rng);
        let id = (client_id << 32) | k as u64;
        let traced = ctx.rec.traced(id);
        let (tx, rx) = mpsc::channel();
        let arrive_us = ctx.clock.now_us();
        let deadline_us = arrive_us + ctx.deadline_us;
        // with admission off, skip the gate's inputs too — queue.len()
        // takes the queue lock, and this is the enqueue hot path
        let fanout_cap = if ctx.adm.policy() == AdmissionPolicy::None {
            None
        } else {
            let (shard, depth) = ctx.shard_and_depth(node);
            match ctx.adm.decide(
                arrive_us,
                deadline_us,
                shard,
                ctx.queue.len(),
                depth,
            ) {
                AdmitDecision::Shed => {
                    if traced {
                        ctx.rec.instant(
                            TRACK_CLIENT,
                            EventKind::Shed,
                            arrive_us,
                            id,
                            0,
                            0,
                            0,
                        );
                    }
                    continue;
                }
                AdmitDecision::Admit => None,
                AdmitDecision::Degrade(f) => {
                    if traced {
                        ctx.rec.instant(
                            TRACK_CLIENT,
                            EventKind::Degrade,
                            arrive_us,
                            id,
                            f.first().copied().unwrap_or(0) as u32,
                            0,
                            0,
                        );
                    }
                    Some(f)
                }
            }
        };
        let req = Request {
            id,
            node,
            label: ctx.labels[node as usize],
            arrive_us,
            deadline_us,
            fanout_cap,
            reply: tx,
        };
        if ctx.queue.push(req).is_err() {
            return; // queue closed under us
        }
        if traced {
            ctx.rec.instant(
                TRACK_CLIENT,
                EventKind::Enqueue,
                arrive_us,
                id,
                0,
                0,
                0,
            );
        }
        let Ok(reply) = rx.recv() else { return };
        // stamp latency at batch completion (the reply's timestamp),
        // exactly like the open-loop collector and the per-shard
        // percentiles — both loops report the same quantity
        let (evaluated, correct) = score_reply(&reply);
        let rec = ReqRecord {
            latency_us: reply.finish_us.saturating_sub(arrive_us),
            deadline_missed: reply.finish_us > deadline_us,
            error: reply.error,
            evaluated,
            correct,
        };
        ctx.records.lock().unwrap().push(rec);
    }
}

/// One open-loop client: issue requests at Poisson times with
/// per-client rate `rate_rps`, never waiting for replies (all requests
/// share `reply_tx`, drained by [`collector_loop`]). Admission runs
/// atomically with the enqueue via [`RequestQueue::push_gated`]; a
/// full queue is a drop-tail shed.
pub fn open_loop_client(
    client_id: u64,
    ctx: &ClientCtx<'_>,
    rate_rps: f64,
    reply_tx: mpsc::Sender<Reply>,
) {
    let mut rng = client_rng(ctx.lcfg, client_id);
    let mut next_us = ctx.clock.now_us();
    for k in 0..ctx.lcfg.requests_per_client {
        next_us =
            next_us.saturating_add(poisson_interarrival_us(&mut rng, rate_rps));
        let now = ctx.clock.now_us();
        if next_us > now {
            std::thread::sleep(Duration::from_micros(next_us - now));
        }
        let node = ctx.sample_node(&mut rng);
        let id = (client_id << 32) | k as u64;
        let traced = ctx.rec.traced(id);
        let arrive_us = ctx.clock.now_us();
        let deadline_us = arrive_us + ctx.deadline_us;
        let (shard, depth) = ctx.shard_and_depth(node);
        let req = Request {
            id,
            node,
            label: ctx.labels[node as usize],
            arrive_us,
            deadline_us,
            fanout_cap: None,
            reply: reply_tx.clone(),
        };
        let mut degraded_f0: Option<u32> = None;
        let pushed = ctx.queue.push_gated(req, |qlen, r| {
            match ctx.adm.decide(arrive_us, deadline_us, shard, qlen, depth) {
                AdmitDecision::Shed => false,
                AdmitDecision::Admit => true,
                AdmitDecision::Degrade(f) => {
                    degraded_f0 = Some(f.first().copied().unwrap_or(0) as u32);
                    r.fanout_cap = Some(f);
                    true
                }
            }
        });
        match pushed {
            Ok(()) => {
                if traced {
                    if let Some(f0) = degraded_f0 {
                        ctx.rec.instant(
                            TRACK_CLIENT,
                            EventKind::Degrade,
                            arrive_us,
                            id,
                            f0,
                            0,
                            0,
                        );
                    }
                    ctx.rec.instant(
                        TRACK_CLIENT,
                        EventKind::Enqueue,
                        arrive_us,
                        id,
                        0,
                        0,
                        0,
                    );
                }
            }
            // the controller already counted the admission shed
            Err(PushRejected::Denied(_)) => {
                if traced {
                    ctx.rec.instant(
                        TRACK_CLIENT,
                        EventKind::Shed,
                        arrive_us,
                        id,
                        0,
                        0,
                        0,
                    );
                }
            }
            // bounded queue overflow: drop-tail shed, counted here
            Err(PushRejected::Full(_)) => {
                ctx.adm.note_shed(shard);
                if traced {
                    ctx.rec.instant(
                        TRACK_CLIENT,
                        EventKind::Shed,
                        arrive_us,
                        id,
                        1,
                        0,
                        0,
                    );
                }
            }
            Err(PushRejected::Closed(_)) => return,
        }
    }
}

/// Open-loop reply collector: drain completions into `records` until
/// every reply sender (one clone per in-flight request, one per
/// client) has been dropped.
pub fn collector_loop(
    rx: mpsc::Receiver<Reply>,
    deadline_us: u64,
    records: &Mutex<Vec<ReqRecord>>,
) {
    while let Ok(rep) = rx.recv() {
        let latency_us = rep.finish_us.saturating_sub(rep.arrive_us);
        let (evaluated, correct) = score_reply(&rep);
        let rec = ReqRecord {
            latency_us,
            deadline_missed: latency_us > deadline_us,
            error: rep.error,
            evaluated,
            correct,
        };
        records.lock().unwrap().push(rec);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zipf_is_skewed_toward_low_ranks() {
        let z = ZipfSampler::new(1000, 1.2);
        let mut rng = Rng::new(4);
        let mut low = 0usize;
        let draws = 20_000;
        for _ in 0..draws {
            if z.sample(&mut rng) < 10 {
                low += 1;
            }
        }
        // top-1% of ranks should draw far more than 1% of traffic
        assert!(low > draws / 10, "only {low}/{draws} in top-10 ranks");
    }

    #[test]
    fn zipf_zero_exponent_is_roughly_uniform() {
        let z = ZipfSampler::new(100, 0.0);
        let mut rng = Rng::new(5);
        let mut counts = [0usize; 100];
        for _ in 0..50_000 {
            counts[z.sample(&mut rng)] += 1;
        }
        let max = *counts.iter().max().unwrap() as f64;
        let min = *counts.iter().min().unwrap() as f64;
        assert!(max / min.max(1.0) < 2.0, "uniform draw too skewed");
    }

    #[test]
    fn zipf_samples_in_range() {
        let z = ZipfSampler::new(7, 1.1);
        let mut rng = Rng::new(6);
        for _ in 0..1000 {
            assert!(z.sample(&mut rng) < 7);
        }
    }

    #[test]
    fn popularity_perm_is_a_permutation() {
        let p = popularity_perm(500, 9);
        let mut sorted = p.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..500u32).collect::<Vec<_>>());
        assert_ne!(p, (0..500u32).collect::<Vec<_>>());
    }

    /// Statistical check: empirical rank frequencies match the Zipf
    /// pmf `p(r) ∝ (r+1)^-s` at a fixed seed. 200k draws over 50 ranks
    /// put the expected per-rank sampling error well below the bounds
    /// asserted here (the seed makes the test exactly reproducible).
    #[test]
    fn zipf_empirical_frequencies_match_pmf() {
        let n = 50usize;
        let s = 1.1f64;
        let draws = 200_000usize;
        let z = ZipfSampler::new(n, s);
        let mut rng = Rng::new(42);
        let mut counts = vec![0usize; n];
        for _ in 0..draws {
            counts[z.sample(&mut rng)] += 1;
        }
        // reference pmf
        let weights: Vec<f64> =
            (0..n).map(|r| 1.0 / ((r + 1) as f64).powf(s)).collect();
        let total: f64 = weights.iter().sum();
        let pmf: Vec<f64> = weights.iter().map(|w| w / total).collect();
        // total-variation distance over all ranks
        let tv: f64 = counts
            .iter()
            .zip(&pmf)
            .map(|(&c, &p)| (c as f64 / draws as f64 - p).abs())
            .sum::<f64>()
            / 2.0;
        assert!(tv < 0.01, "TV distance to Zipf pmf too large: {tv:.4}");
        // the head rank individually: ~27% of mass, tight relative bound
        let emp0 = counts[0] as f64 / draws as f64;
        let rel = (emp0 - pmf[0]).abs() / pmf[0];
        assert!(rel < 0.05, "head rank off by {:.1}%", rel * 100.0);
        // monotone-ish: the pmf head must dominate the tail empirically
        assert!(counts[0] > counts[n - 1] * 5, "no Zipf skew visible");
    }

    /// The popularity permutation is a pure function of (n, seed):
    /// bitwise-identical across calls, different across seeds.
    #[test]
    fn popularity_perm_is_bitwise_stable_across_calls() {
        let a = popularity_perm(1_000, 7);
        let b = popularity_perm(1_000, 7);
        assert_eq!(a, b, "same (n, seed) must give the same permutation");
        let c = popularity_perm(1_000, 8);
        assert_ne!(a, c, "different seed must reshuffle");
    }

    #[test]
    fn arrival_parses_and_labels() {
        assert_eq!(Arrival::parse("closed").unwrap(), Arrival::Closed);
        assert_eq!(
            Arrival::parse("poisson:5000").unwrap(),
            Arrival::Poisson { rate_rps: 5000.0 }
        );
        assert_eq!(
            Arrival::parse("poisson:2500.5").unwrap().offered_rps(),
            Some(2500.5)
        );
        assert_eq!(Arrival::Closed.label(), "closed");
        assert_eq!(
            Arrival::Poisson { rate_rps: 5000.0 }.label(),
            "poisson:5000"
        );
        assert!(Arrival::parse("open").is_err());
        assert!(Arrival::parse("poisson:").is_err());
        assert!(Arrival::parse("poisson:abc").is_err());
        assert!(Arrival::parse("poisson:0").is_err());
        assert!(Arrival::parse("poisson:-5").is_err());
    }

    /// Statistical check on the Poisson arrival process: exponential
    /// inter-arrival gaps at rate λ have mean 1/λ and squared
    /// coefficient of variation 1. 100k draws at a fixed seed put the
    /// sampling error of both statistics far inside the asserted
    /// bounds (mean ±2.5%, CV² ±10%).
    #[test]
    fn poisson_interarrivals_match_configured_rate() {
        let rate = 1_000.0f64; // mean gap 1000 µs
        let draws = 100_000usize;
        let mut rng = Rng::new(77);
        let mut sum = 0.0f64;
        let mut sumsq = 0.0f64;
        for _ in 0..draws {
            let dt = poisson_interarrival_us(&mut rng, rate) as f64;
            sum += dt;
            sumsq += dt * dt;
        }
        let mean = sum / draws as f64;
        let var = sumsq / draws as f64 - mean * mean;
        let cv2 = var / (mean * mean);
        assert!(
            (mean - 1_000.0).abs() < 25.0,
            "mean inter-arrival {mean:.1} µs != 1/rate"
        );
        assert!(
            (cv2 - 1.0).abs() < 0.1,
            "CV^2 {cv2:.3} not exponential-like"
        );
    }

    /// Doubling the rate halves the mean gap (rate knob actually
    /// steers offered load).
    #[test]
    fn poisson_rate_scales_inversely() {
        let mean_at = |rate: f64, seed: u64| -> f64 {
            let mut rng = Rng::new(seed);
            let n = 20_000;
            (0..n)
                .map(|_| poisson_interarrival_us(&mut rng, rate) as f64)
                .sum::<f64>()
                / n as f64
        };
        let m1 = mean_at(2_000.0, 3);
        let m2 = mean_at(4_000.0, 3);
        let ratio = m1 / m2;
        assert!((ratio - 2.0).abs() < 0.15, "rate scaling off: {ratio:.2}");
    }
}
