//! Closed-loop load generator: N client threads replay a Zipf-skewed
//! request trace against the serving queue, each blocking on its reply
//! before issuing the next request (so offered load adapts to server
//! capacity, and every latency sample includes queueing).
//!
//! Popularity is assigned by a seeded random permutation (rank →
//! node), so hot nodes scatter across communities instead of
//! clustering in the low ids the community reordering produces —
//! community locality must then be *recovered* by the batcher's knob,
//! which is exactly what the benchmark measures.

use std::sync::mpsc;
use std::sync::Mutex;

use crate::util::rng::Rng;

use super::queue::RequestQueue;
use super::{Request, ServeClock};

#[derive(Clone, Debug)]
pub struct LoadConfig {
    pub clients: usize,
    pub requests_per_client: usize,
    /// Zipf exponent (1.0–1.3 is typical web skew; 0 = uniform).
    pub zipf_s: f64,
    pub seed: u64,
}

/// Per-request record collected by the clients.
#[derive(Clone, Copy, Debug)]
pub struct ReqRecord {
    pub latency_us: u64,
    pub deadline_missed: bool,
    /// The reply carried an executor error (its latency is excluded
    /// from the report's percentiles).
    pub error: bool,
}

/// Rank → node popularity mapping (seeded shuffle of all node ids).
pub fn popularity_perm(n: usize, seed: u64) -> Vec<u32> {
    let mut perm: Vec<u32> = (0..n as u32).collect();
    let mut rng = Rng::new(seed ^ 0x21F0_5EED);
    rng.shuffle(&mut perm);
    perm
}

/// Zipf(rank) sampler over `0..n` via a precomputed CDF + binary
/// search; built once and shared read-only across client threads.
pub struct ZipfSampler {
    cdf: Vec<f64>,
}

impl ZipfSampler {
    pub fn new(n: usize, s: f64) -> ZipfSampler {
        let n = n.max(1);
        let mut cdf = Vec::with_capacity(n);
        let mut acc = 0.0f64;
        for r in 0..n {
            acc += 1.0 / ((r + 1) as f64).powf(s);
            cdf.push(acc);
        }
        ZipfSampler { cdf }
    }

    pub fn sample(&self, rng: &mut Rng) -> usize {
        let total = *self.cdf.last().unwrap();
        let x = rng.f64() * total;
        match self.cdf.binary_search_by(|v| v.partial_cmp(&x).unwrap()) {
            Ok(i) => i,
            Err(i) => i.min(self.cdf.len() - 1),
        }
    }
}

/// One closed-loop client: sample node → enqueue → block on reply →
/// record latency → repeat.
#[allow(clippy::too_many_arguments)]
pub fn client_loop(
    client_id: u64,
    queue: &RequestQueue<Request>,
    clock: &ServeClock,
    lcfg: &LoadConfig,
    deadline_us: u64,
    perm: &[u32],
    zipf: &ZipfSampler,
    records: &Mutex<Vec<ReqRecord>>,
) {
    let mut rng = Rng::new(
        lcfg.seed ^ (client_id.wrapping_add(1)).wrapping_mul(0xA24B_AED4_963E_E407),
    );
    for k in 0..lcfg.requests_per_client {
        let rank = zipf.sample(&mut rng);
        let node = perm[rank];
        let (tx, rx) = mpsc::channel();
        let arrive_us = clock.now_us();
        let req = Request {
            id: (client_id << 32) | k as u64,
            node,
            arrive_us,
            deadline_us: arrive_us + deadline_us,
            reply: tx,
        };
        if queue.push(req).is_err() {
            return; // queue closed under us
        }
        let Ok(reply) = rx.recv() else { return };
        let done_us = clock.now_us();
        let rec = ReqRecord {
            latency_us: done_us.saturating_sub(arrive_us),
            deadline_missed: done_us > arrive_us + deadline_us,
            error: reply.error,
        };
        records.lock().unwrap().push(rec);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zipf_is_skewed_toward_low_ranks() {
        let z = ZipfSampler::new(1000, 1.2);
        let mut rng = Rng::new(4);
        let mut low = 0usize;
        let draws = 20_000;
        for _ in 0..draws {
            if z.sample(&mut rng) < 10 {
                low += 1;
            }
        }
        // top-1% of ranks should draw far more than 1% of traffic
        assert!(low > draws / 10, "only {low}/{draws} in top-10 ranks");
    }

    #[test]
    fn zipf_zero_exponent_is_roughly_uniform() {
        let z = ZipfSampler::new(100, 0.0);
        let mut rng = Rng::new(5);
        let mut counts = [0usize; 100];
        for _ in 0..50_000 {
            counts[z.sample(&mut rng)] += 1;
        }
        let max = *counts.iter().max().unwrap() as f64;
        let min = *counts.iter().min().unwrap() as f64;
        assert!(max / min.max(1.0) < 2.0, "uniform draw too skewed");
    }

    #[test]
    fn zipf_samples_in_range() {
        let z = ZipfSampler::new(7, 1.1);
        let mut rng = Rng::new(6);
        for _ in 0..1000 {
            assert!(z.sample(&mut rng) < 7);
        }
    }

    #[test]
    fn popularity_perm_is_a_permutation() {
        let p = popularity_perm(500, 9);
        let mut sorted = p.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..500u32).collect::<Vec<_>>());
        assert_ne!(p, (0..500u32).collect::<Vec<_>>());
    }

    /// Statistical check: empirical rank frequencies match the Zipf
    /// pmf `p(r) ∝ (r+1)^-s` at a fixed seed. 200k draws over 50 ranks
    /// put the expected per-rank sampling error well below the bounds
    /// asserted here (the seed makes the test exactly reproducible).
    #[test]
    fn zipf_empirical_frequencies_match_pmf() {
        let n = 50usize;
        let s = 1.1f64;
        let draws = 200_000usize;
        let z = ZipfSampler::new(n, s);
        let mut rng = Rng::new(42);
        let mut counts = vec![0usize; n];
        for _ in 0..draws {
            counts[z.sample(&mut rng)] += 1;
        }
        // reference pmf
        let weights: Vec<f64> =
            (0..n).map(|r| 1.0 / ((r + 1) as f64).powf(s)).collect();
        let total: f64 = weights.iter().sum();
        let pmf: Vec<f64> = weights.iter().map(|w| w / total).collect();
        // total-variation distance over all ranks
        let tv: f64 = counts
            .iter()
            .zip(&pmf)
            .map(|(&c, &p)| (c as f64 / draws as f64 - p).abs())
            .sum::<f64>()
            / 2.0;
        assert!(tv < 0.01, "TV distance to Zipf pmf too large: {tv:.4}");
        // the head rank individually: ~27% of mass, tight relative bound
        let emp0 = counts[0] as f64 / draws as f64;
        let rel = (emp0 - pmf[0]).abs() / pmf[0];
        assert!(rel < 0.05, "head rank off by {:.1}%", rel * 100.0);
        // monotone-ish: the pmf head must dominate the tail empirically
        assert!(counts[0] > counts[n - 1] * 5, "no Zipf skew visible");
    }

    /// The popularity permutation is a pure function of (n, seed):
    /// bitwise-identical across calls, different across seeds.
    #[test]
    fn popularity_perm_is_bitwise_stable_across_calls() {
        let a = popularity_perm(1_000, 7);
        let b = popularity_perm(1_000, 7);
        assert_eq!(a, b, "same (n, seed) must give the same permutation");
        let c = popularity_perm(1_000, 8);
        assert_ne!(a, c, "different seed must reshuffle");
    }
}
