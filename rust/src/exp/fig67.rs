//! Figures 6 and 7 — the two correlation studies behind Figure 5's
//! trends, recomputed from the fig5 sweep data:
//!
//! * Fig. 6 — per-epoch (modeled) time vs mean input-feature bytes per
//!   batch, with the Pearson correlation per dataset. COMM-RAND's
//!   speedups come from shrinking each batch's feature footprint.
//! * Fig. 7 — epochs-until-convergence vs mean distinct labels per
//!   batch. Lower label diversity (more community bias) delays
//!   convergence.

use anyhow::Result;

use crate::util::json::{num, obj, s, Json};
use crate::util::stats::pearson;

use super::common::*;
use super::fig5;

pub fn run_fig6(ctx: &mut Ctx) -> Result<()> {
    let data = fig5::load_or_run(ctx)?;
    let mut md = String::from(
        "# Figure 6 — per-epoch time vs input feature size\n\n",
    );
    let mut jout = Vec::new();
    for (ds, rows) in data.as_obj()? {
        let rows = rows.as_arr()?;
        let xs: Vec<f64> = rows
            .iter()
            .map(|r| r.get("input_bytes").unwrap().as_f64().unwrap() / 1e6)
            .collect();
        let ys: Vec<f64> = rows
            .iter()
            .map(|r| r.get("epoch_modeled_s").unwrap().as_f64().unwrap() * 1e3)
            .collect();
        let r = pearson(&xs, &ys);
        md.push_str(&format!("\n## {ds} (pearson r = {r:.3})\n\n"));
        let mut t =
            Table::new(&["policy", "p", "input MB/batch", "epoch time (ms)"]);
        for (i, row) in rows.iter().enumerate() {
            t.row(vec![
                row.get("policy")?.as_str()?.to_string(),
                format!("{:.1}", row.get("p")?.as_f64()?),
                f2(xs[i]),
                format!("{:.3}", ys[i]),
            ]);
        }
        md.push_str(&t.to_markdown());
        jout.push(obj(vec![
            ("dataset", s(ds)),
            ("pearson", num(r)),
        ]));
    }
    write_results("fig6", &md, &Json::Arr(jout))
}

pub fn run_fig7(ctx: &mut Ctx) -> Result<()> {
    let data = fig5::load_or_run(ctx)?;
    let mut md = String::from(
        "# Figure 7 — convergence vs label diversity per batch\n\n",
    );
    let mut jout = Vec::new();
    for (ds, rows) in data.as_obj()? {
        // labels/batch is a root-partitioning property; average over p
        // (the paper notes p has no effect on label counts)
        let rows = rows.as_arr()?;
        let mut by_policy: std::collections::BTreeMap<String, (f64, f64, usize)> =
            Default::default();
        for r in rows {
            let label = r.get("policy")?.as_str()?;
            let root = label.split('+').next().unwrap_or(label).to_string();
            let e = by_policy.entry(root).or_insert((0.0, 0.0, 0));
            e.0 += r.get("labels_per_batch")?.as_f64()?;
            e.1 += r.get("converged_epochs")?.as_f64()?;
            e.2 += 1;
        }
        let xs: Vec<f64> =
            by_policy.values().map(|(l, _, n)| l / *n as f64).collect();
        let ys: Vec<f64> =
            by_policy.values().map(|(_, c, n)| c / *n as f64).collect();
        let r = pearson(&xs, &ys);
        md.push_str(&format!("\n## {ds} (pearson r = {r:.3})\n\n"));
        let mut t =
            Table::new(&["root policy", "labels/batch", "epochs to converge"]);
        for (k, (l, c, n)) in &by_policy {
            t.row(vec![
                k.clone(),
                f2(l / *n as f64),
                f2(c / *n as f64),
            ]);
        }
        md.push_str(&t.to_markdown());
        jout.push(obj(vec![("dataset", s(ds)), ("pearson", num(r))]));
    }
    write_results("fig7", &md, &Json::Arr(jout))
}
