//! Shared experiment plumbing: budgets (quick vs full), result file
//! emission, the policy grids, and cached multi-config training runs.

use std::path::PathBuf;

use anyhow::Result;

use crate::config::{preset, BatchPolicy, DatasetPreset, TrainConfig};
use crate::graph::Dataset;
use crate::sampler::RootPolicy;
use crate::train::{self, Method, RunOptions, Session, TrainReport};
use crate::util::json::Json;

/// Quick mode (env COMM_RAND_QUICK=1): fewer epochs / single seed so
/// `cargo bench figures` finishes in minutes. Full budgets are used by
/// `comm-rand exp <id>`.
pub fn quick() -> bool {
    fast() || std::env::var("COMM_RAND_QUICK").map(|v| v == "1").unwrap_or(false)
}

/// Fastest tier (env COMM_RAND_FAST=1): smoke-level budgets used by the
/// `figures` bench target so `cargo bench` stays minutes-scale.
pub fn fast() -> bool {
    std::env::var("COMM_RAND_FAST").map(|v| v == "1").unwrap_or(false)
}

pub fn seeds() -> Vec<u64> {
    if quick() {
        vec![0]
    } else {
        vec![0, 1]
    }
}

pub fn max_epochs() -> usize {
    if fast() {
        3
    } else if quick() {
        8
    } else {
        24
    }
}

pub fn results_dir() -> PathBuf {
    let d = PathBuf::from("results");
    std::fs::create_dir_all(&d).ok();
    d
}

pub fn write_results(id: &str, markdown: &str, json: &Json) -> Result<()> {
    let dir = results_dir();
    std::fs::write(dir.join(format!("{id}.md")), markdown)?;
    std::fs::write(dir.join(format!("{id}.json")), json.to_string_pretty())?;
    println!("{markdown}");
    println!("[exp] wrote results/{id}.md and results/{id}.json");
    Ok(())
}

/// The Figure-5 policy grid: (label, root policy) x p values.
pub fn root_grid() -> Vec<RootPolicy> {
    RootPolicy::figure5_set()
}

pub fn p_grid() -> Vec<f64> {
    vec![0.5, 0.9, 1.0]
}

/// The paper's best COMM-RAND knobs (§6.1.3).
pub fn best_policy() -> BatchPolicy {
    BatchPolicy { roots: RootPolicy::CommRandMix { pct: 0.125 }, p_intra: 1.0 }
}

pub struct Ctx {
    pub session: Session,
}

impl Ctx {
    pub fn new() -> Result<Ctx> {
        Ok(Ctx { session: Session::new()? })
    }

    pub fn dataset(&self, name: &str) -> Result<(DatasetPreset, Dataset)> {
        let p = preset(name)
            .ok_or_else(|| anyhow::anyhow!("unknown preset {name}"))?;
        let ds = train::dataset::load_or_build(&p, true)?;
        Ok((p, ds))
    }

    /// One training run with the dataset's nominal cache model.
    pub fn run(
        &mut self,
        p: &DatasetPreset,
        ds: &Dataset,
        method: &Method,
        cfg: &TrainConfig,
        opts_mod: impl FnOnce(&mut RunOptions),
    ) -> Result<TrainReport> {
        let mut opts = RunOptions { l2_base: p.l2_base, ..Default::default() };
        opts_mod(&mut opts);
        train::train(&mut self.session, ds, p.artifact, method, cfg, &opts)
    }

    /// Mean over seeds of a metric extracted from per-seed reports.
    pub fn run_seeds(
        &mut self,
        p: &DatasetPreset,
        ds: &Dataset,
        method: &Method,
        base_cfg: &TrainConfig,
    ) -> Result<Vec<TrainReport>> {
        let mut out = Vec::new();
        for s in seeds() {
            let cfg = TrainConfig { seed: s, ..base_cfg.clone() };
            out.push(self.run(p, ds, method, &cfg, |_| {})?);
        }
        Ok(out)
    }
}

/// Aggregates over per-seed reports.
pub struct Agg {
    pub val_acc: f64,
    pub epoch_modeled_s: f64,
    pub epoch_wall_s: f64,
    pub converged_epochs: f64,
    pub total_modeled_s: f64,
    pub total_wall_s: f64,
    pub input_bytes: f64,
    pub labels_per_batch: f64,
    pub l2_miss: f64,
}

pub fn aggregate(reports: &[TrainReport]) -> Agg {
    let n = reports.len().max(1) as f64;
    let sum = |f: &dyn Fn(&TrainReport) -> f64| -> f64 {
        reports.iter().map(|r| f(r)).sum::<f64>() / n
    };
    Agg {
        val_acc: sum(&|r| r.best_val_acc),
        epoch_modeled_s: sum(&|r| r.mean_epoch_modeled_s()),
        epoch_wall_s: sum(&|r| r.mean_epoch_wall_s()),
        converged_epochs: sum(&|r| r.converged_epoch as f64),
        total_modeled_s: sum(&|r| r.modeled_to_convergence()),
        total_wall_s: sum(&|r| r.wall_to_convergence()),
        input_bytes: sum(&|r| r.mean_input_bytes()),
        labels_per_batch: sum(&|r| r.mean_labels_per_batch()),
        l2_miss: sum(&|r| {
            let k = r.epochs.len().max(1) as f64;
            r.epochs.iter().map(|e| e.l2_miss_rate).sum::<f64>() / k
        }),
    }
}

/// Markdown table builder.
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(cols: &[&str]) -> Table {
        Table {
            header: cols.iter().map(|s| s.to_string()).collect(),
            rows: vec![],
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.header.len());
        self.rows.push(cells);
    }

    pub fn to_markdown(&self) -> String {
        let mut s = String::new();
        s.push_str(&format!("| {} |\n", self.header.join(" | ")));
        s.push_str(&format!(
            "|{}\n",
            "---|".repeat(self.header.len())
        ));
        for r in &self.rows {
            s.push_str(&format!("| {} |\n", r.join(" | ")));
        }
        s
    }
}

pub fn f2(x: f64) -> String {
    format!("{x:.2}")
}

pub fn f4(x: f64) -> String {
    format!("{x:.4}")
}

pub fn pct(x: f64) -> String {
    format!("{:.2}%", x * 100.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_markdown() {
        let mut t = Table::new(&["a", "b"]);
        t.row(vec!["1".into(), "2".into()]);
        let md = t.to_markdown();
        assert!(md.contains("| a | b |"));
        assert!(md.contains("| 1 | 2 |"));
    }
}
