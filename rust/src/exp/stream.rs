//! Streaming-churn sweep: serve throughput and accuracy versus graph
//! mutation rate, with incremental community maintenance against the
//! naive full-relabel baseline.
//!
//! Three closed-loop runs over the same Zipf trace:
//!
//! * **zero-churn** — `mutate=0`, the frozen-graph reference;
//! * **incremental** — churn at the configured rate with bounded
//!   local refinement (`maint=incr`): label snapshots republish in
//!   microseconds, full relabels only on modularity-drift;
//! * **full-relabel** — the same churn with the naive baseline
//!   (`maint=full`): every update epoch runs a stop-the-world Louvain
//!   relabel, rebuilds the shard plan and flushes the feature caches.
//!
//! The sweep is also the acceptance gate for the mutation subsystem
//! and FAILS unless (a) incremental maintenance sustains ≥ 90 % of the
//! zero-churn throughput, (b) the naive baseline degrades throughput
//! below the incremental run, and (c) accuracy stays within 1 point of
//! zero-churn. (With the host reference executor, logits depend only
//! on the root's precomputed aggregation row, so the accuracy gate
//! guards reply routing under churn — mis-fanned logits rows would
//! show up here — rather than model-quality drift.)
//!
//! Needs no PJRT session: like `exp serve` it uses the compiled infer
//! artifact when present and the host executor otherwise.

use anyhow::{bail, Result};

use crate::cli::Args;
use crate::config::preset;
use crate::serve::{engine, Arrival, LoadConfig, ServeConfig, ServeReport};
use crate::stream::MaintenanceMode;
use crate::util::json::{num, obj, Json};

use super::common::{f2, pct, quick, write_results, Table};

pub fn run(args: &Args) -> Result<()> {
    let name = args.pos.get(1).map(String::as_str).unwrap_or("reddit_sim");
    let p = preset(name)
        .ok_or_else(|| anyhow::anyhow!("unknown preset {name}"))?;
    let ds = crate::train::dataset::load_or_build(&p, true)?;

    let mut scfg = ServeConfig::for_dataset(&ds);
    scfg.batch_size = args.get_usize("batch", 32)?;
    scfg.seed = args.get_u64("seed", 0)?;
    scfg.mutate_epoch = args.get_usize("mutate_epoch", 64)?;
    scfg.drift_threshold = args.get_f64("drift", 0.15)?;
    let rate = args.get_f64("mutate", 2_000.0)?;
    if !(rate.is_finite() && rate > 0.0) {
        bail!("mutate= must be a positive churn rate, got {rate}");
    }
    let lcfg = LoadConfig {
        clients: args.get_usize("clients", 8)?,
        requests_per_client: args
            .get_usize("requests", if quick() { 100 } else { 300 })?,
        zipf_s: args.get_f64("zipf", 1.1)?,
        arrival: Arrival::Closed,
        seed: scfg.seed ^ 0x57E4,
    };
    let (exec, meta) = engine::build_executor(&p, &ds, &scfg)?;

    let modes: [(&str, f64, MaintenanceMode); 3] = [
        ("zero-churn", 0.0, MaintenanceMode::Incremental),
        ("incremental", rate, MaintenanceMode::Incremental),
        ("full-relabel", rate, MaintenanceMode::Full),
    ];
    let mut table = Table::new(&[
        "mode",
        "churn ups",
        "req/s",
        "p50 ms",
        "p99 ms",
        "acc",
        "cache hit",
        "stale",
        "waves",
        "full relabels",
        "drift",
    ]);
    let mut reps: Vec<(String, ServeReport)> = Vec::new();
    for (label, mutate, maint) in modes {
        let cfg = ServeConfig {
            mutate_rps: mutate,
            maintenance: maint,
            ..scfg.clone()
        };
        let rep = engine::run(&ds, &meta, exec.as_ref(), &cfg, &lcfg)?;
        println!("{}", rep.summary());
        // the stale-hit accounting invariant must hold on every run
        if rep.cache_lookups != rep.cache_hits + rep.cache_misses + rep.stale_hits
        {
            bail!(
                "[exp stream] {label}: cache accounting broken: {} lookups \
                 != {} hits + {} misses + {} stale",
                rep.cache_lookups,
                rep.cache_hits,
                rep.cache_misses,
                rep.stale_hits
            );
        }
        let (waves, fulls, drift) = match &rep.stream {
            Some(st) => (st.relabel_waves, st.full_relabels, st.drift),
            None => (0, 0, 0.0),
        };
        let acc = if rep.evaluated > 0 {
            pct(rep.accuracy)
        } else {
            "n/a".to_string()
        };
        table.row(vec![
            label.to_string(),
            format!("{mutate:.0}"),
            format!("{:.0}", rep.throughput_rps),
            f2(rep.lat_p50_ms),
            f2(rep.lat_p99_ms),
            acc,
            pct(rep.cache_hit_rate),
            format!("{}", rep.stale_hits),
            format!("{waves}"),
            format!("{fulls}"),
            format!("{drift:.4}"),
        ]);
        reps.push((label.to_string(), rep));
    }

    let zero = &reps[0].1;
    let incr = &reps[1].1;
    let full = &reps[2].1;
    let incr_ratio = incr.throughput_rps / zero.throughput_rps.max(1e-9);
    let full_ratio = full.throughput_rps / zero.throughput_rps.max(1e-9);
    let acc_drop = if zero.evaluated > 0 && incr.evaluated > 0 {
        zero.accuracy - incr.accuracy
    } else {
        0.0
    };
    let verdict = format!(
        "incremental sustains {:.0}% of zero-churn throughput \
         (gate: >= 90%); naive full-relabel sustains {:.0}% \
         (gate: < 90% and < incremental); accuracy drop {:.2} points \
         (gate: <= 1.0)",
        incr_ratio * 100.0,
        full_ratio * 100.0,
        acc_drop * 100.0,
    );
    println!("[exp stream] {verdict}");

    let md = format!(
        "# Streaming churn — throughput & accuracy vs mutation rate \
         ({name})\n\n\
         Closed loop: {} clients x {} requests, zipf {}, batch cap {}, \
         executor `{}`; churn {} updates/s in epochs of {} (30% feature \
         rewrites / 35% inserts / 35% deletes), drift threshold {}.\n\n\
         {}\n{}\n",
        lcfg.clients,
        lcfg.requests_per_client,
        lcfg.zipf_s,
        scfg.batch_size,
        exec.name(),
        rate,
        scfg.mutate_epoch,
        scfg.drift_threshold,
        table.to_markdown(),
        verdict,
    );
    let json = obj(vec![
        ("dataset", crate::util::json::s(name)),
        ("mutate_ups", num(rate)),
        (
            "runs",
            obj(reps
                .iter()
                .map(|(label, rep)| (label.as_str(), rep.to_json()))
                .collect::<Vec<(&str, Json)>>()),
        ),
        (
            "gates",
            obj(vec![
                ("incr_throughput_ratio", num(incr_ratio)),
                ("full_throughput_ratio", num(full_ratio)),
                ("accuracy_drop", num(acc_drop)),
            ]),
        ),
    ]);
    write_results("stream", &md, &json)?;
    // the CI churn-smoke job uploads this artifact by name
    std::fs::write(
        super::common::results_dir().join("stream_bench.json"),
        json.to_string_pretty(),
    )?;
    println!("[exp stream] wrote results/stream_bench.json");

    // acceptance gates (see the module docs)
    if incr_ratio < 0.90 {
        bail!(
            "[exp stream] FAIL: incremental maintenance sustained only \
             {:.0}% of zero-churn throughput (need >= 90%)",
            incr_ratio * 100.0
        );
    }
    if full_ratio >= 0.90 {
        bail!(
            "[exp stream] FAIL: naive full-relabel baseline sustained \
             {:.0}% of zero-churn throughput — it must NOT reach the 90% \
             bar (stop-the-world relabels are the cost incremental \
             maintenance exists to avoid)",
            full_ratio * 100.0
        );
    }
    if full_ratio >= incr_ratio {
        bail!(
            "[exp stream] FAIL: naive full-relabel baseline ({:.0}%) did \
             not degrade below incremental ({:.0}%) — the maintainer is \
             not earning its keep",
            full_ratio * 100.0,
            incr_ratio * 100.0
        );
    }
    if acc_drop > 0.01 + 1e-9 {
        bail!(
            "[exp stream] FAIL: accuracy under churn dropped {:.2} points \
             from zero-churn (allowed: 1.0)",
            acc_drop * 100.0
        );
    }
    Ok(())
}
