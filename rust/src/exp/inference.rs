//! §3's inference-reordering study: full-graph GCN inference on the
//! community-reordered vs randomly-ordered graph. Inference is
//! order-sensitive only through memory locality, so the cache model
//! (sequential full-graph feature/edge traversal) shows the reordering
//! win the paper quotes (up to 26%, 12% average), while accuracy is
//! identical by construction.

use anyhow::Result;

use crate::cachesim::lru::CacheConfig;
use crate::cachesim::{DeviceModel, EpochCost, SetAssocCache};
use crate::community::random_order;
use crate::util::json::{num, obj, s, Json};
use crate::util::rng::Rng;
use crate::util::timer::Timer;

use super::common::*;

pub fn run(ctx: &mut Ctx) -> Result<()> {
    let (p, ds) = ctx.dataset("reddit_sim")?;
    // randomly-ordered variant of the same graph
    let mut ds_rand = crate::train::dataset::build(&p, true);
    let mut rng = Rng::new(0x1AFE);
    let perm = random_order(ds_rand.n(), &mut rng);
    ds_rand.permute(&perm);

    let device = DeviceModel::default();
    let mut results = Vec::new();
    for (label, d) in [("community-ordered", &ds), ("random-ordered", &ds_rand)] {
        // full-graph inference access pattern: for each node (in id
        // order), read its feature row and its neighbors' rows — the
        // A'XW gather the fullbatch artifact performs.
        let mut l2 = SetAssocCache::new(CacheConfig::a100_l2(p.l2_base));
        let t = Timer::start();
        for v in 0..d.n() as u32 {
            l2.access_row(v, d.feat_dim);
            for &u in d.csr.neighbors(v) {
                l2.access_row(u, d.feat_dim);
            }
        }
        let replay_s = t.elapsed_s();
        let mut cost = EpochCost::default();
        cost.add_cache(&l2);
        cost.batches = 1;
        // dense term: |V| rows through the 3-layer GCN
        cost.add_dense(
            &[d.n(), d.n(), d.n(), d.n()],
            &[d.feat_dim, 64, 64, d.num_classes],
        );
        let modeled = cost.seconds(&device);
        println!(
            "[inference] {label}: miss {:.4}, modeled {:.2}ms (replay {:.2}s)",
            l2.miss_rate(),
            modeled * 1e3,
            replay_s
        );
        results.push((label, l2.miss_rate(), modeled));
    }

    let (_, miss_c, t_c) = (results[0].0, results[0].1, results[0].2);
    let (_, miss_r, t_r) = (results[1].0, results[1].1, results[1].2);
    let mut md = String::from(
        "# §3 — community reordering and full-graph inference (reddit_sim)\n\n",
    );
    let mut t = Table::new(&["ordering", "L2 miss rate", "modeled time (ms)"]);
    t.row(vec!["community".into(), f4(miss_c), format!("{:.2}", t_c * 1e3)]);
    t.row(vec!["random".into(), f4(miss_r), format!("{:.2}", t_r * 1e3)]);
    md.push_str(&t.to_markdown());
    md.push_str(&format!(
        "\nreordering cuts modeled inference time by {:.1}% \
         (paper: up to 26%, 12% average).\n",
        100.0 * (1.0 - t_c / t_r)
    ));
    let json = Json::Arr(vec![
        obj(vec![
            ("ordering", s("community")),
            ("miss", num(miss_c)),
            ("modeled_s", num(t_c)),
        ]),
        obj(vec![
            ("ordering", s("random")),
            ("miss", num(miss_r)),
            ("modeled_s", num(t_r)),
        ]),
    ]);
    let _ = ctx; // session unused beyond dataset loading
    write_results("inference", &md, &json)
}
