//! Live-health gate (`comm-rand exp health`): ramp offered load past
//! saturation and prove the temporal health layer earns its keep.
//!
//! A health layer that misses real incidents, cries wolf in steady
//! state, or taxes the serving path is worse than none, so this
//! experiment drives the same bench through four phases and **fails**
//! unless all of them hold:
//!
//! 1. **Steady** — closed loop under a generous SLO: zero alert
//!    transitions, zero watchdog stalls, zero postmortems (no
//!    false positives when nothing is wrong).
//! 2. **Capacity** — the steady run's throughput fixes the saturation
//!    point for phase 3.
//! 3. **Saturation** — open-loop Poisson at ~3× capacity with
//!    `admission=reject`, a tight SLO, the flight recorder, and
//!    full-rate tracing: an alert must fire within two slow lookback
//!    spans of the first burn-rate breach, the postmortem bundle must
//!    re-parse via [`read_postmortem`], and the Chrome trace must
//!    carry the `slo_fire` instant.
//! 4. **Overhead** — best-of-N closed-loop trials with the health
//!    layer off vs on: enabling `health_ms=` + `slo=` may cost at
//!    most [`MAX_OVERHEAD_FRAC`] of baseline throughput.
//!
//! Like `exp serve` / `exp obs` this needs no PJRT session
//! (host-executor fallback), so it runs — and gates CI — in
//! artifact-less environments.

use anyhow::{bail, Result};

use crate::cli::Args;
use crate::config::preset;
use crate::obs::{read_postmortem, SloSpec};
use crate::serve::{
    engine, AdmissionPolicy, Arrival, LoadConfig, ServeConfig,
};
use crate::util::json::{num, obj, s, Json};

use super::common::{f2, quick, results_dir, write_results, Table};

/// Enabling the health layer may cost at most this fraction of
/// health-off throughput (the ≤ 5 % acceptance bar).
pub const MAX_OVERHEAD_FRAC: f64 = 0.05;

pub fn run(args: &Args) -> Result<()> {
    let name = args.pos.get(1).map(String::as_str).unwrap_or("tiny");
    let p = preset(name)
        .ok_or_else(|| anyhow::anyhow!("unknown preset {name}"))?;
    let ds = crate::train::dataset::load_or_build(&p, true)?;

    let mut base = ServeConfig::for_dataset(&ds);
    base.batch_size = args.get_usize("batch", 32)?;
    base.workers = args.get_usize("workers", base.workers)?;
    base.shards = args.get_usize("shards", 2)?;
    base.seed = args.get_u64("seed", 0)?;
    let health_ms = args.get_u64("health_ms", 25)?.max(1);
    let clients = args.get_usize("clients", 4)?;
    let requests = args
        .get_usize("requests", if quick() { 60 } else { 200 })?;
    let trials =
        args.get_usize("trials", if quick() { 2 } else { 3 })?.max(1);
    let closed = LoadConfig {
        clients,
        requests_per_client: requests,
        zipf_s: args.get_f64("zipf", 1.1)?,
        arrival: Arrival::Closed,
        seed: base.seed ^ 0x10AD,
    };
    let (exec, meta) = engine::build_executor(&p, &ds, &base)?;

    let mut table = Table::new(&[
        "phase",
        "arrival",
        "req/s",
        "p99 ms",
        "windows",
        "fired",
        "stalls",
    ]);

    // ---- phase 1: steady state under a generous SLO ----
    let steady_cfg = ServeConfig {
        health_ms,
        slo: Some(SloSpec::parse("p99_ms=5000,shed=0.5,err=0.5")?),
        ..base.clone()
    };
    let steady = engine::run(&ds, &meta, exec.as_ref(), &steady_cfg, &closed)?;
    println!("[health] steady: {}", steady.summary());
    let sh = steady
        .health
        .as_ref()
        .ok_or_else(|| anyhow::anyhow!("steady run reported no health"))?;
    if sh.windows_sealed < 2 {
        bail!(
            "steady run sealed only {} health window(s); lengthen the run \
             or shrink health_ms ({health_ms} ms)",
            sh.windows_sealed
        );
    }
    if sh.transitions != 0 || sh.alerts.iter().any(|a| a.fired > 0) {
        bail!(
            "steady-state false positive: {} alert transition(s) under a \
             generous SLO ({})",
            sh.transitions,
            steady.summary()
        );
    }
    if !sh.stalled_threads.is_empty() {
        bail!(
            "watchdog declared {:?} stalled in a healthy run",
            sh.stalled_threads
        );
    }
    if !sh.postmortems.is_empty() {
        bail!("flight recorder fired {} bundle(s) in a healthy run",
              sh.postmortems.len());
    }
    if !steady.unjoined_threads.is_empty() {
        bail!("steady run left threads unjoined: {:?}",
              steady.unjoined_threads);
    }
    table.row(vec![
        "steady".into(),
        "closed".into(),
        format!("{:.0}", steady.throughput_rps),
        f2(steady.lat_p99_ms),
        sh.windows_sealed.to_string(),
        "0".into(),
        "0".into(),
    ]);

    // ---- phase 2: the steady throughput fixes the saturation point ----
    let capacity = steady.throughput_rps.max(1.0);
    let sat_rate = (capacity * 3.0).max(200.0);

    // ---- phase 3: open-loop overload with the full layer armed ----
    // Run long enough to seal a healthy number of windows at the
    // offered rate (open-loop duration ≈ total requests / rate).
    let sat_windows = if quick() { 12 } else { 24 };
    let sat_total = ((sat_rate * (sat_windows as f64 * health_ms as f64
        / 1_000.0))
        .ceil() as usize)
        .max(clients * 50);
    let trace_path = results_dir().join("health_trace.json");
    let sat_spec = format!(
        "p99_ms={:.3},shed=0.05,fast=1,slow=3,burn=1,clear=2",
        (steady.lat_p99_ms * 2.0).max(1.0)
    );
    let sat_slo = SloSpec::parse(&sat_spec)?;
    let sat_cfg = ServeConfig {
        health_ms,
        slo: Some(sat_slo.clone()),
        flight: Some(results_dir()),
        trace: Some(trace_path.clone()),
        trace_sample: 1000,
        admission: AdmissionPolicy::Reject,
        ..base.clone()
    };
    let sat_load = LoadConfig {
        clients,
        requests_per_client: sat_total.div_ceil(clients),
        arrival: Arrival::Poisson { rate_rps: sat_rate },
        ..closed.clone()
    };
    println!(
        "[health] saturating: capacity ~{capacity:.0} req/s, offering \
         {sat_rate:.0} req/s open-loop ({} requests, slo {})",
        sat_load.clients * sat_load.requests_per_client,
        sat_slo.label()
    );
    let sat = engine::run(&ds, &meta, exec.as_ref(), &sat_cfg, &sat_load)?;
    println!("[health] saturated: {}", sat.summary());
    let hh = sat
        .health
        .as_ref()
        .ok_or_else(|| anyhow::anyhow!("saturation run reported no health"))?;

    let fired: Vec<_> = hh.alerts.iter().filter(|a| a.fired > 0).collect();
    if fired.is_empty() {
        bail!(
            "no SLO alert fired at {:.0} req/s offered over ~{:.0} req/s \
             capacity ({})",
            sat_rate,
            capacity,
            sat.summary()
        );
    }
    // Reactivity: the fire transition must land within two slow
    // lookback spans of the first fast-burn breach.
    let budget_us = 2 * sat_slo.slow_windows as u64 * health_ms * 1_000;
    for a in &fired {
        let (breach, fire) = match (a.first_breach_us, a.first_fire_us) {
            (Some(b), Some(f)) => (b, f),
            _ => bail!("alert {} fired without breach/fire timestamps", a.slo),
        };
        let lag = fire.saturating_sub(breach);
        println!(
            "[health] alert {}: breach at {} µs, fire at {} µs \
             (lag {} µs, budget {} µs)",
            a.slo, breach, fire, lag, budget_us
        );
        if lag > budget_us {
            bail!(
                "alert {} took {lag} µs from breach to fire \
                 (> {budget_us} µs = 2 slow spans)",
                a.slo
            );
        }
    }

    // Flight recorder: exactly the bundles the report names, and each
    // must survive a full re-parse.
    if hh.postmortems.is_empty() {
        bail!("alert fired but the flight recorder produced no postmortem");
    }
    let bundle = read_postmortem(&hh.postmortems[0])?;
    if bundle.windows == 0 {
        bail!(
            "postmortem at {} carries no health windows",
            hh.postmortems[0].display()
        );
    }
    println!(
        "[health] postmortem ok: {} (reason {}, {} windows, {} span \
         events, {} transitions)",
        hh.postmortems[0].display(),
        bundle.reason,
        bundle.windows,
        bundle.span_events,
        bundle.alert_transitions
    );

    // The fire transition must also land in the Chrome trace.
    let slo_fire_events = count_trace_events(&trace_path, "slo_fire")?;
    if slo_fire_events == 0 {
        bail!(
            "trace at {} has no slo_fire instants despite {} fire \
             transition(s)",
            trace_path.display(),
            hh.transitions
        );
    }
    table.row(vec![
        "saturate".into(),
        format!("poisson:{sat_rate:.0}"),
        format!("{:.0}", sat.throughput_rps),
        f2(sat.lat_p99_ms),
        hh.windows_sealed.to_string(),
        fired.iter().map(|a| a.fired).sum::<u64>().to_string(),
        hh.stalled_threads.len().to_string(),
    ]);

    // ---- phase 4: the overhead gate ----
    let on_cfg = ServeConfig {
        health_ms,
        slo: Some(SloSpec::parse("default")?),
        ..base.clone()
    };
    let mut best_off = 0.0f64;
    let mut best_on = 0.0f64;
    for t in 0..trials {
        let l = LoadConfig { seed: closed.seed ^ t as u64, ..closed.clone() };
        let off = engine::run(&ds, &meta, exec.as_ref(), &base, &l)?;
        let on = engine::run(&ds, &meta, exec.as_ref(), &on_cfg, &l)?;
        println!(
            "[health] overhead trial {t}: off {:.0} req/s, on {:.0} req/s",
            off.throughput_rps, on.throughput_rps
        );
        best_off = best_off.max(off.throughput_rps);
        best_on = best_on.max(on.throughput_rps);
    }
    let overhead = 1.0 - best_on / best_off.max(1e-9);
    println!(
        "[health] health-layer overhead: {:+.2}% of baseline throughput \
         ({:.0} -> {:.0} req/s, gate {:.0}%)",
        overhead * 100.0,
        best_off,
        best_on,
        MAX_OVERHEAD_FRAC * 100.0
    );
    if overhead > MAX_OVERHEAD_FRAC {
        bail!(
            "health layer costs {:.1}% throughput (> {:.0}% budget): \
             {:.0} req/s off vs {:.0} req/s on",
            overhead * 100.0,
            MAX_OVERHEAD_FRAC * 100.0,
            best_off,
            best_on
        );
    }
    table.row(vec![
        "overhead".into(),
        "closed".into(),
        format!("{best_on:.0}"),
        "-".into(),
        "-".into(),
        "-".into(),
        format!("{:+.1}%", overhead * 100.0),
    ]);

    let md = format!(
        "# Live-health gate ({name})\n\n\
         Steady phase: {} clients x {} closed-loop requests under a \
         generous SLO — {} windows sealed, zero transitions, zero \
         stalls. Saturation phase: poisson arrivals at {:.0} req/s \
         (~3x the {:.0} req/s closed-loop capacity), `{}`, \
         admission=reject — {} fire transition(s), breach→fire lag \
         within {} µs, postmortem `{}` re-parsed ({} windows, {} span \
         events). Health-layer overhead {:+.2}% (budget {:.0}%), best \
         of {} trial(s).\n\n{}\n",
        closed.clients,
        closed.requests_per_client,
        sh.windows_sealed,
        sat_rate,
        capacity,
        sat_slo.label(),
        fired.iter().map(|a| a.fired).sum::<u64>(),
        budget_us,
        hh.postmortems[0].display(),
        bundle.windows,
        bundle.span_events,
        overhead * 100.0,
        MAX_OVERHEAD_FRAC * 100.0,
        trials,
        table.to_markdown()
    );
    let json = obj(vec![
        ("preset", s(name)),
        ("health_ms", num(health_ms as f64)),
        ("capacity_rps", num(capacity)),
        ("offered_rps", num(sat_rate)),
        ("steady", steady.to_json()),
        ("saturated", sat.to_json()),
        ("slo", s(&sat_slo.label())),
        ("fire_lag_budget_us", num(budget_us as f64)),
        ("slo_fire_trace_events", num(slo_fire_events as f64)),
        (
            "postmortem",
            obj(vec![
                ("dir", s(&hh.postmortems[0].display().to_string())),
                ("reason", s(&bundle.reason)),
                ("windows", num(bundle.windows as f64)),
                ("span_events", num(bundle.span_events as f64)),
                (
                    "alert_transitions",
                    num(bundle.alert_transitions as f64),
                ),
            ]),
        ),
        ("overhead_frac", num(overhead)),
        ("overhead_budget_frac", num(MAX_OVERHEAD_FRAC)),
    ]);
    write_results("health_bench", &md, &json)
}

/// Count named events in an exported Chrome trace (any phase — the
/// SLO transitions land as instants, the locality windows as counter
/// samples; shared with `exp locality`).
pub(crate) fn count_trace_events(
    path: &std::path::Path,
    name: &str,
) -> Result<usize> {
    let doc = Json::parse_file(path)?;
    let mut n = 0;
    for ev in doc.get("traceEvents")?.as_arr()? {
        if ev.get("name")?.as_str()? == name {
            n += 1;
        }
    }
    Ok(n)
}
