//! §6.5.3 — pre-processing overhead: community detection (Louvain, the
//! RABBIT stand-in) + relabeling, as a fraction of the baseline's
//! total training time (paper: 0.78% for reddit).

use anyhow::Result;

use crate::config::{preset, BatchPolicy, TrainConfig};
use crate::train::{dataset, Method};
use crate::util::json::{num, obj, s};

use super::common::*;

pub fn run(ctx: &mut Ctx) -> Result<()> {
    let p = preset("reddit_sim").unwrap();
    let (ds, t_louvain, t_permute) = dataset::build_timed(&p);
    println!(
        "[preproc] louvain {t_louvain:.3}s + permute {t_permute:.3}s \
         ({} communities)",
        ds.num_comms
    );

    let cfg = TrainConfig { max_epochs: max_epochs(), ..Default::default() };
    let r = ctx.run(
        &p, &ds, &Method::CommRand(BatchPolicy::baseline()), &cfg, |_| {})?;
    let train_wall = r.total_wall_s();
    let overhead = (t_louvain + t_permute) / train_wall.max(1e-9);

    let mut md = String::from("# §6.5.3 — pre-processing overhead (reddit_sim)\n\n");
    let mut t = Table::new(&["stage", "seconds"]);
    t.row(vec!["community detection (louvain)".into(), format!("{t_louvain:.3}")]);
    t.row(vec!["relabel + permute".into(), format!("{t_permute:.3}")]);
    t.row(vec![
        format!("baseline training ({} epochs, wall)", r.epochs.len()),
        format!("{train_wall:.1}"),
    ]);
    md.push_str(&t.to_markdown());
    md.push_str(&format!(
        "\nreordering overhead = {:.2}% of one baseline training run \
         (paper: 0.78%); COMM-RAND additionally amortizes it across \
         inference and repeated runs.\n",
        overhead * 100.0
    ));
    let json = obj(vec![
        ("louvain_s", num(t_louvain)),
        ("permute_s", num(t_permute)),
        ("train_wall_s", num(train_wall)),
        ("overhead_frac", num(overhead)),
        ("dataset", s("reddit_sim")),
    ]);
    write_results("preproc", &md, &json)
}
