//! Table 3 — fixed-budget hyper-parameter tuning (§6.2): random-search
//! both the baseline (lr, batch size) and COMM-RAND (lr, batch size,
//! root policy, p) under the same wall-clock search budget, then train
//! each winner under the same training budget. COMM-RAND's faster
//! epochs buy more search trials *and* more training epochs.
//!
//! Budgets are scaled from the paper's 1h/30min to seconds (env
//! COMM_RAND_TUNE_S / COMM_RAND_TRAIN_S override).

use anyhow::Result;
use std::time::Instant;

use crate::config::{BatchPolicy, TrainConfig};
use crate::sampler::RootPolicy;
use crate::train::{self, Method};
use crate::util::json::{num, obj, s, Json};
use crate::util::rng::Rng;

use super::common::*;

fn env_s(key: &str, default: f64) -> f64 {
    std::env::var(key)
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

fn sample_common(rng: &mut Rng) -> (f32, usize) {
    let lrs = [3e-4f32, 1e-3, 3e-3];
    let batches = [128usize, 256];
    (
        lrs[rng.usize_below(lrs.len())],
        batches[rng.usize_below(batches.len())],
    )
}

fn sample_commrand_policy(rng: &mut Rng) -> BatchPolicy {
    let roots = [
        RootPolicy::CommRandMix { pct: 0.0 },
        RootPolicy::CommRandMix { pct: 0.125 },
        RootPolicy::CommRandMix { pct: 0.25 },
        RootPolicy::CommRandMix { pct: 0.50 },
    ];
    let ps = [0.9, 1.0];
    BatchPolicy {
        roots: roots[rng.usize_below(roots.len())],
        p_intra: ps[rng.usize_below(ps.len())],
    }
}

/// Random search within `budget_s`; each trial trains a few epochs and
/// is scored by val accuracy. Returns (best_cfg, best_policy, trials).
fn search(
    ctx: &mut Ctx,
    p: &crate::config::DatasetPreset,
    ds: &crate::graph::Dataset,
    comm_rand: bool,
    budget_s: f64,
) -> Result<(TrainConfig, BatchPolicy, usize, f64)> {
    let mut rng = Rng::new(0xB07);
    let t0 = Instant::now();
    let mut best_acc = -1.0;
    let mut best: Option<(TrainConfig, BatchPolicy)> = None;
    let mut trials = 0;
    while t0.elapsed().as_secs_f64() < budget_s {
        let (lr, batch) = sample_common(&mut rng);
        let pol = if comm_rand {
            sample_commrand_policy(&mut rng)
        } else {
            BatchPolicy::baseline()
        };
        let cfg = TrainConfig {
            lr,
            batch_size: batch,
            max_epochs: 3,
            seed: trials as u64,
            ..Default::default()
        };
        let r = ctx.run(p, ds, &Method::CommRand(pol.clone()), &cfg, |_| {})?;
        trials += 1;
        if r.best_val_acc > best_acc {
            best_acc = r.best_val_acc;
            best = Some((cfg, pol));
        }
    }
    let (cfg, pol) = best.unwrap();
    Ok((cfg, pol, trials, best_acc))
}

/// Train under a fixed *device-time* budget (modeled A100 seconds —
/// on this CPU testbed wall-clock does not express the GPU cache
/// speedups, so the paper's "same 30min budget" is applied in modeled
/// time; see EXPERIMENTS.md). Returns (epochs, val acc, test acc).
fn budget_train(
    ctx: &mut Ctx,
    p: &crate::config::DatasetPreset,
    ds: &crate::graph::Dataset,
    cfg: &TrainConfig,
    pol: &BatchPolicy,
    baseline_epoch_units: f64,
    base_modeled_epoch_s: f64,
) -> Result<(f64, f64, f64)> {
    // estimate modeled epoch cost from a 1-epoch run
    let probe_cfg = TrainConfig { max_epochs: 1, ..cfg.clone() };
    let probe = ctx.run(p, ds, &Method::CommRand(pol.clone()), &probe_cfg, |_| {})?;
    let per_epoch = probe.mean_epoch_modeled_s().max(1e-9);
    let budget = baseline_epoch_units * base_modeled_epoch_s;
    let epochs = ((budget / per_epoch).floor() as usize).clamp(1, 60);
    let full_cfg = TrainConfig {
        max_epochs: epochs,
        patience: usize::MAX, // fixed budget: no early stop
        ..cfg.clone()
    };
    let r = ctx.run(p, ds, &Method::CommRand(pol.clone()), &full_cfg, |_| {})?;

    // test accuracy with final params: retrain state is gone; reuse the
    // report's best val accuracy and re-evaluate test via a fresh short
    // run is wasteful — instead use train::run_training internals. For
    // simplicity we re-run evaluation inside train() — report test as
    // val-acc proxy plus a dedicated test pass:
    let test_acc = {
        let train_meta = ctx.session.meta(&format!("{}.train", p.artifact))?;
        let infer_meta = ctx.session.meta(&format!("{}.infer", p.artifact))?;
        // quick re-train to the same epoch count to regain params
        let mut state = crate::runtime::TrainState::new(
            &ctx.session.rt,
            &train_meta,
            Some(&infer_meta),
            Some(ds),
            full_cfg.lr,
            full_cfg.seed,
        )?;
        // replay epochs without instrumentation
        let train_nodes = ds.train_nodes();
        let mut epoch_rng = Rng::new(full_cfg.seed ^ 0xE90C);
        for epoch in 0..epochs.min(30) {
            let order = crate::sampler::roots::order_roots(
                pol.roots, &train_nodes, &ds.community, &mut epoch_rng,
            );
            let plan = train::loader::EpochPlan {
                batch_roots: order
                    .chunks(full_cfg.batch_size)
                    .map(|c| c.to_vec())
                    .collect(),
                gen: train::loader::BatchGen::Sampled {
                    policy: if pol.p_intra <= 0.5 {
                        crate::sampler::NeighborPolicy::Uniform
                    } else {
                        crate::sampler::NeighborPolicy::Biased { p: pol.p_intra }
                    },
                },
                seed: full_cfg.seed
                    ^ (epoch as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15),
            };
            train::loader::run_epoch(
                ds, &train_meta, &plan, train::default_workers(), true,
                |_i, b| state.step(&b).map(|_| ()),
            )?;
        }
        train::test_accuracy(&state, ds, &infer_meta, full_cfg.seed)?
    };
    Ok((epochs as f64, r.best_val_acc, test_acc))
}

pub fn run(ctx: &mut Ctx) -> Result<()> {
    let (p, ds) = ctx.dataset("reddit_sim")?;
    let (tune_s, train_s) = if quick() {
        (env_s("COMM_RAND_TUNE_S", 20.0), env_s("COMM_RAND_TRAIN_S", 15.0))
    } else {
        (env_s("COMM_RAND_TUNE_S", 90.0), env_s("COMM_RAND_TRAIN_S", 60.0))
    };

    println!("[tab3] searching baseline ({tune_s}s budget)...");
    let (cfg_b, pol_b, trials_b, _) = search(ctx, &p, &ds, false, tune_s)?;
    println!("[tab3] searching comm-rand ({tune_s}s budget)...");
    let (cfg_c, pol_c, trials_c, _) = search(ctx, &p, &ds, true, tune_s)?;

    // baseline modeled epoch time defines the shared device budget
    let probe_cfg = TrainConfig { max_epochs: 1, ..cfg_b.clone() };
    let base_probe = ctx.run(
        &p, &ds, &Method::CommRand(pol_b.clone()), &probe_cfg, |_| {})?;
    let base_modeled = base_probe.mean_epoch_modeled_s();
    // scaled budget: the baseline gets `train_s`-worth of epochs at ~1
    // epoch/s equivalent (quick: ~12, full: ~24 baseline epochs)
    let units = (train_s / 4.0).clamp(4.0, 24.0);
    println!("[tab3] budget-training baseline ({units:.0} baseline-epoch units)...");
    let (ep_b, val_b, test_b) =
        budget_train(ctx, &p, &ds, &cfg_b, &pol_b, units, base_modeled)?;
    println!("[tab3] budget-training comm-rand (same device budget)...");
    let (ep_c, val_c, test_c) =
        budget_train(ctx, &p, &ds, &cfg_c, &pol_c, units, base_modeled)?;

    let mut md = String::from(
        "# Table 3 — fixed-budget hyper-parameter tuning (reddit_sim)\n\n",
    );
    md.push_str(&format!(
        "search budget {tune_s}s wall; training budget = {:.0} \
         baseline-epoch units of *modeled device time* shared by both \
         schemes (paper: 1h / 30min on the A100; see EXPERIMENTS.md \
         §Known-deviations)\n\n",
        (train_s / 4.0).clamp(4.0, 24.0),
    ));
    let mut t = Table::new(&[
        "", "search trials", "epochs trained", "final val acc", "test acc",
    ]);
    t.row(vec![
        "Baseline".into(),
        trials_b.to_string(),
        format!("{ep_b:.0}"),
        pct(val_b),
        pct(test_b),
    ]);
    t.row(vec![
        format!("COMM-RAND ({} p={})", pol_c.roots.label(), pol_c.p_intra),
        trials_c.to_string(),
        format!("{ep_c:.0}"),
        pct(val_c),
        pct(test_c),
    ]);
    md.push_str(&t.to_markdown());
    let json = Json::Arr(vec![
        obj(vec![
            ("scheme", s("baseline")),
            ("trials", num(trials_b as f64)),
            ("epochs", num(ep_b)),
            ("val_acc", num(val_b)),
            ("test_acc", num(test_b)),
            ("lr", num(cfg_b.lr as f64)),
            ("batch", num(cfg_b.batch_size as f64)),
        ]),
        obj(vec![
            ("scheme", s("comm-rand")),
            ("trials", num(trials_c as f64)),
            ("epochs", num(ep_c)),
            ("val_acc", num(val_c)),
            ("test_acc", num(test_c)),
            ("lr", num(cfg_c.lr as f64)),
            ("batch", num(cfg_c.batch_size as f64)),
            ("policy", s(&pol_c.label())),
        ]),
    ]);
    write_results("tab3", &md, &json)
}
