//! Quantized-inference gate: accuracy parity and kernel speedup for
//! the i16q path (`comm-rand exp quant`).
//!
//! Pipeline: train the host model for a few epochs, quantize the final
//! checkpoint to the on-disk `i16q` dtype ([`crate::ckpt::quant`]),
//! write it out and reload it from disk (so the full format round-trip
//! is on the gated path), then replay one identical closed-loop Zipf
//! trace three ways:
//!
//! 1. f32 checkpoint, `kernel=scalar` — the baseline;
//! 2. quantized checkpoint, `kernel=scalar` — portable integer path;
//! 3. quantized checkpoint, `kernel=auto` — best SIMD backend here.
//!
//! Gates (any failure exits non-zero, so CI pins all of them):
//!
//! * **accuracy** — quantized top-1 within 0.5 points of f32;
//! * **determinism** — runs 2 and 3 agree *exactly* (accuracy and
//!   evaluated count): host logits depend only on the root node and
//!   the installed parameters, and every kernel variant returns
//!   bit-identical accumulators, so the backend cannot change a single
//!   prediction;
//! * **equivalence** — both kernels re-checked in-process on the real
//!   trained model: bitwise-equal accumulators across every backend
//!   this machine can run;
//! * **throughput** — the quantized matvec at the auto backend must
//!   clear 2× the scalar-f32 classifier on the same trained
//!   parameters (skipped with a note when auto resolves to scalar,
//!   e.g. under `COMM_RAND_KERNEL=scalar`);
//! * **zero errors** in every serve run, and the quantized runs must
//!   report their execute spans under the `i16q` dtype.
//!
//! Writes `results/quant_bench.json` (uploaded by the CI `quant-gate`
//! job) plus the usual `results/quant.{md,json}` pair.

use std::time::Instant;

use anyhow::{bail, Result};

use crate::ckpt::quant::{pick_exp, FEAT_LIMIT, FEAT_MAX_EXP};
use crate::ckpt::{quantize_checkpoint, Checkpoint, CheckpointWriter, Retention};
use crate::cli::Args;
use crate::config::{preset, TrainConfig};
use crate::runtime::host;
use crate::runtime::kernels::{
    accumulate_rows_i8, matvec_i16_i32, pad_to_lanes, KernelBackend,
};
use crate::serve::{engine, Arrival, LoadConfig, ServeConfig, ServeReport};
use crate::train::train_host;
use crate::util::json::{arr, num, obj, s, Json};

use super::common::{f2, pct, quick, results_dir, write_results, Table};

/// Quantized accuracy must stay within this of the f32 baseline
/// (absolute top-1 fraction; 0.005 = the issue's "0.5 points").
const ACC_TOLERANCE: f64 = 0.005;

/// Required speedup of the auto-backend quantized matvec over the
/// scalar f32 classifier (waived when auto *is* scalar).
const MIN_SPEEDUP: f64 = 2.0;

pub fn run(args: &Args) -> Result<()> {
    let name = args.pos.get(1).map(String::as_str).unwrap_or("tiny");
    let p = preset(name)
        .ok_or_else(|| anyhow::anyhow!("unknown preset {name}"))?;
    let ds = crate::train::dataset::load_or_build(&p, true)?;
    let seed = args.get_u64("seed", 0)?;
    let epochs = args.get_usize("epochs", if quick() { 4 } else { 8 })?;

    // ---- train a real model, keep the final checkpoint ----
    let dir = results_dir().join(format!("quant-{name}"));
    std::fs::remove_dir_all(&dir).ok();
    let mut writer = CheckpointWriter::new(&dir, 1, Retention::BestAndLatest)?;
    let tcfg = TrainConfig {
        batch_size: 256,
        lr: 0.5,
        max_epochs: epochs,
        seed,
        ..Default::default()
    };
    let (_, treport) = train_host(&ds, &tcfg, Some(&mut writer), false)?;
    println!("{}", treport.summary());
    let last = writer
        .entries()
        .iter()
        .max_by_key(|e| e.epoch)
        .ok_or_else(|| anyhow::anyhow!("trainer wrote no checkpoint"))?
        .clone();

    // ---- quantize it and push it through the on-disk format ----
    let ck = Checkpoint::load(&last.path)?;
    let qck = quantize_checkpoint(&ck)?;
    let qpath = dir.join("ckpt-q.bin");
    qck.write_atomic(&qpath)?;
    let qck = Checkpoint::load(&qpath)?; // serve what the disk has
    if qck.quant.is_none() {
        bail!("quantized checkpoint lost its i16 tensors on reload");
    }

    // ---- one trace, three kernel/dtype configurations ----
    let mut scfg = ServeConfig::for_dataset(&ds);
    scfg.batch_size = 32;
    scfg.fanouts = vec![5, 5];
    scfg.seed = seed;
    let lcfg = LoadConfig {
        clients: 4,
        requests_per_client: args
            .get_usize("requests", if quick() { 40 } else { 120 })?,
        zipf_s: args.get_f64("zipf", 1.1)?,
        arrival: Arrival::Closed,
        seed: seed ^ 0x10AD,
    };
    let meta =
        engine::synthetic_infer_meta(&ds, scfg.batch_size, &scfg.fanouts);

    let mut table = Table::new(&[
        "run",
        "kernel",
        "dtype",
        "serve acc",
        "req/s",
        "exec µs/batch",
        "p99 ms",
    ]);
    let mut rows = Vec::new();
    let mut serve_one = |label: &str,
                         ckpt: Option<&std::path::Path>,
                         kernel: &str|
     -> Result<ServeReport> {
        let cfg = ServeConfig {
            ckpt: ckpt.map(|p| p.to_path_buf()),
            kernel: kernel.to_string(),
            ..scfg.clone()
        };
        let exec = crate::serve::HostExecutor::with_backend(
            &ds,
            cfg.seed,
            KernelBackend::resolve(kernel)?,
        )?;
        let rep = engine::run(&ds, &meta, &exec, &cfg, &lcfg)?;
        println!("{}", rep.summary());
        let dtype = rep
            .execute
            .iter()
            .map(|e| e.dtype)
            .collect::<Vec<_>>()
            .join("+");
        let exec_us = rep.execute.iter().map(|e| e.mean_us).sum::<f64>();
        table.row(vec![
            label.to_string(),
            kernel.to_string(),
            dtype.clone(),
            pct(rep.accuracy),
            format!("{:.0}", rep.throughput_rps),
            format!("{exec_us:.0}"),
            f2(rep.lat_p99_ms),
        ]);
        rows.push(obj(vec![
            ("run", s(label)),
            ("kernel", s(kernel)),
            ("dtype", s(&dtype)),
            ("accuracy", num(rep.accuracy)),
            ("evaluated", num(rep.evaluated as f64)),
            ("errors", num(rep.errors as f64)),
            ("throughput_rps", num(rep.throughput_rps)),
            ("execute_mean_us", num(exec_us)),
            ("param_version", num(rep.param_version as f64)),
        ]));
        Ok(rep)
    };

    let rep_f32 = serve_one("f32", Some(&last.path), "scalar")?;
    let rep_qs = serve_one("quant", Some(&qpath), "scalar")?;
    let rep_qa = serve_one("quant", Some(&qpath), "auto")?;
    drop(serve_one); // release the table/rows borrows

    // ---- gates ----
    let mut failures: Vec<String> = Vec::new();
    for (label, rep) in
        [("f32", &rep_f32), ("quant-scalar", &rep_qs), ("quant-auto", &rep_qa)]
    {
        if rep.errors != 0 {
            failures.push(format!("{label}: {} executor errors", rep.errors));
        }
        if rep.evaluated == 0 {
            failures.push(format!("{label}: nothing evaluated"));
        }
        if rep.param_version != 1 {
            failures.push(format!(
                "{label}: served param_version {} (expected the installed \
                 checkpoint, version 1)",
                rep.param_version
            ));
        }
    }
    for (label, rep) in [("quant-scalar", &rep_qs), ("quant-auto", &rep_qa)] {
        if !rep.execute.iter().any(|e| e.dtype == "i16q") {
            failures.push(format!(
                "{label}: no i16q execute spans in the report (dtypes: {:?})",
                rep.execute.iter().map(|e| e.dtype).collect::<Vec<_>>()
            ));
        }
    }
    if (rep_qs.accuracy, rep_qs.evaluated)
        != (rep_qa.accuracy, rep_qa.evaluated)
    {
        failures.push(format!(
            "kernel determinism broken: scalar served {:.6} over {} vs auto \
             {:.6} over {}",
            rep_qs.accuracy, rep_qs.evaluated, rep_qa.accuracy,
            rep_qa.evaluated
        ));
    }
    let acc_gap = (rep_qa.accuracy - rep_f32.accuracy).abs();
    if acc_gap > ACC_TOLERANCE {
        failures.push(format!(
            "quantized accuracy {:.4} drifted {:.4} from f32 {:.4} \
             (tolerance {ACC_TOLERANCE})",
            rep_qa.accuracy, acc_gap, rep_f32.accuracy
        ));
    }

    // ---- in-process kernel equivalence + microbenchmark ----
    let auto = KernelBackend::resolve(&scfg.kernel)?;
    let bench = kernel_bench(&ds, &qck, auto, &mut failures)?;
    println!(
        "[exp] matvec: scalar-f32 {:.1} ns/node, {} i16 {:.1} ns/node \
         (speedup {:.2}x)",
        bench.f32_ns, auto.name(), bench.quant_ns, bench.speedup
    );
    if auto == KernelBackend::Scalar {
        println!(
            "[exp] auto kernel resolved to scalar — {MIN_SPEEDUP}x SIMD \
             speedup gate waived (portable-path run)"
        );
    } else if bench.speedup < MIN_SPEEDUP {
        failures.push(format!(
            "quantized {} matvec only {:.2}x the scalar f32 classifier \
             (gate {MIN_SPEEDUP}x)",
            auto.name(),
            bench.speedup
        ));
    }

    let pass = failures.is_empty();
    let bench_json = obj(vec![
        ("dataset", s(name)),
        ("train_epochs", num(epochs as f64)),
        ("auto_backend", s(auto.name())),
        (
            "backends_checked",
            arr(KernelBackend::all_available()
                .iter()
                .map(|b| s(b.name()))
                .collect()),
        ),
        ("f32_accuracy", num(rep_f32.accuracy)),
        ("quant_accuracy", num(rep_qa.accuracy)),
        ("accuracy_gap", num(acc_gap)),
        ("f32_matvec_ns", num(bench.f32_ns)),
        ("quant_matvec_ns", num(bench.quant_ns)),
        ("speedup", num(bench.speedup)),
        ("pass", Json::Bool(pass)),
        (
            "failures",
            arr(failures.iter().map(|f| s(f)).collect()),
        ),
        ("runs", arr(rows.clone())),
    ]);
    std::fs::write(
        results_dir().join("quant_bench.json"),
        bench_json.to_string_pretty(),
    )?;
    println!("[exp] wrote results/quant_bench.json");

    let md = format!(
        "# Quantized inference: accuracy parity + kernel speedup ({name})\n\n\
         Host trainer, {epochs} epochs; the final checkpoint is quantized \
         to `i16q` and both views replay the same closed-loop Zipf trace \
         ({} clients x {} requests).\n\n{}\n\
         f32 accuracy {} vs quantized {} (gap {:.4}, tolerance \
         {ACC_TOLERANCE}); `{}` matvec speedup {:.2}x over scalar f32.\n",
        lcfg.clients,
        lcfg.requests_per_client,
        table.to_markdown(),
        pct(rep_f32.accuracy),
        pct(rep_qa.accuracy),
        acc_gap,
        auto.name(),
        bench.speedup,
    );
    write_results(
        "quant",
        &md,
        &obj(vec![
            ("f32_accuracy", num(rep_f32.accuracy)),
            ("quant_accuracy", num(rep_qa.accuracy)),
            ("speedup", num(bench.speedup)),
            ("runs", arr(rows)),
        ]),
    )?;

    if !pass {
        bail!("quant gate failed:\n  - {}", failures.join("\n  - "));
    }
    Ok(())
}

struct BenchOut {
    f32_ns: f64,
    quant_ns: f64,
    speedup: f64,
}

/// Cross-backend bitwise equivalence on the real trained model, then a
/// wall-clock head-to-head of the classifier inner loop: scalar f32
/// [`host::logits_into`] vs the quantized [`matvec_i16_i32`] at
/// `auto`, both over the same aggregated feature rows.
fn kernel_bench(
    ds: &crate::graph::Dataset,
    qck: &Checkpoint,
    auto: KernelBackend,
    failures: &mut Vec<String>,
) -> Result<BenchOut> {
    let f = ds.feat_dim;
    let c = ds.num_classes;
    let fp = pad_to_lanes(f);
    let qts = qck
        .quant
        .as_ref()
        .ok_or_else(|| anyhow::anyhow!("checkpoint has no quant tensors"))?;

    // quantize activations exactly like the executor: one global
    // power-of-two scale picked over the *raw* feature table (the
    // aggregated rows are means over raw rows, so they fit the same
    // range)
    let agg = host::aggregate_table(ds);
    let mut max_abs = 0f32;
    for v in 0..ds.n() as u32 {
        for &x in ds.feature_row(v) {
            max_abs = max_abs.max(x.abs());
        }
    }
    let qagg_exp = pick_exp(max_abs, FEAT_LIMIT, FEAT_MAX_EXP)?;
    let qscale = (1u64 << qagg_exp) as f32;
    let n = ds.n();
    let mut qagg = vec![0i16; n * fp];
    let mut qfeat = vec![0i8; n * fp];
    for v in 0..n {
        for k in 0..f {
            qagg[v * fp + k] = (agg[v * f + k] * qscale).round() as i16;
            qfeat[v * fp + k] =
                (ds.feature_row(v as u32)[k] * qscale).round() as i8;
        }
    }
    // class-major transposed weights + bias at the combined scale
    let w = &qts[0];
    let b = &qts[1];
    let comb = (1u64 << (w.exp + qagg_exp)) as f64;
    let mut wt = vec![0i16; c * fp];
    for k in 0..f {
        for cls in 0..c {
            wt[cls * fp + k] = w.q[k * c + cls];
        }
    }
    let bias: Vec<i32> =
        b.q.iter().map(|&x| (x as f64 * comb).round() as i32).collect();

    // every runnable backend must agree bitwise with scalar on both
    // kernels, over every node of the real model
    let sample: Vec<u32> = (0..n as u32).collect();
    let mut want = vec![0i32; c];
    let mut got = vec![0i32; c];
    let mut want_acc = vec![0i32; fp];
    let mut got_acc = vec![0i32; fp];
    for backend in KernelBackend::all_available() {
        if backend == KernelBackend::Scalar {
            continue;
        }
        for &v in &sample {
            let row = &qagg[v as usize * fp..(v as usize + 1) * fp];
            matvec_i16_i32(KernelBackend::Scalar, &wt, row, &bias, fp, &mut want);
            matvec_i16_i32(backend, &wt, row, &bias, fp, &mut got);
            if want != got {
                failures.push(format!(
                    "matvec mismatch: {} disagrees with scalar at node {v}",
                    backend.name()
                ));
                break;
            }
            let nbrs = ds.csr.neighbors(v);
            want_acc.iter_mut().for_each(|x| *x = 0);
            got_acc.iter_mut().for_each(|x| *x = 0);
            accumulate_rows_i8(
                KernelBackend::Scalar,
                &qfeat,
                fp,
                nbrs,
                &mut want_acc,
            );
            accumulate_rows_i8(backend, &qfeat, fp, nbrs, &mut got_acc);
            if want_acc != got_acc {
                failures.push(format!(
                    "accumulate mismatch: {} disagrees with scalar at node \
                     {v} ({} neighbors)",
                    backend.name(),
                    nbrs.len()
                ));
                break;
            }
        }
    }

    // head-to-head: whole-table classification, repeated to get
    // stable numbers; black_box keeps the loops from being elided
    let reps = if quick() { 20 } else { 100 };
    let mut fout = vec![0f32; c];
    let t0 = Instant::now();
    for _ in 0..reps {
        for v in 0..n {
            host::logits_into(&qck.params, &agg[v * f..(v + 1) * f], &mut fout);
            std::hint::black_box(&fout);
        }
    }
    let f32_ns = t0.elapsed().as_nanos() as f64 / (reps * n) as f64;
    let mut qout = vec![0i32; c];
    let t1 = Instant::now();
    for _ in 0..reps {
        for v in 0..n {
            matvec_i16_i32(
                auto,
                &wt,
                &qagg[v * fp..(v + 1) * fp],
                &bias,
                fp,
                &mut qout,
            );
            std::hint::black_box(&qout);
        }
    }
    let quant_ns = t1.elapsed().as_nanos() as f64 / (reps * n) as f64;
    Ok(BenchOut { f32_ns, quant_ns, speedup: f32_ns / quant_ns })
}
