//! Experiment harness — one module per paper table/figure. `run`
//! dispatches `comm-rand exp <id>`; every experiment writes
//! `results/<id>.md` + `results/<id>.json` and prints the table.
//!
//! Budget control: env `COMM_RAND_QUICK=1` (set by `cargo bench
//! figures`) shrinks epochs/seeds/datasets; full budgets otherwise.

pub mod ablation;
pub mod autotune;
pub mod ckpt;
pub mod common;
pub mod coop;
pub mod fig10;
pub mod fig2;
pub mod fig5;
pub mod fig67;
pub mod fig8;
pub mod fig9;
pub mod fullbatch;
pub mod health;
pub mod inference;
pub mod locality;
pub mod obs;
pub mod preproc;
pub mod quant;
pub mod serve;
pub mod stream;
pub mod tab3;
pub mod tab4;
pub mod tab5;

use anyhow::{bail, Result};

use crate::cli::Args;
use common::Ctx;

pub fn run(args: &Args) -> Result<()> {
    let id = args.pos.first().map(|s| s.as_str()).unwrap_or("");
    // the serving sweeps and the train→checkpoint→serve pipeline need
    // no PJRT session (they fall back to the host executor), so
    // dispatch them before Ctx loads the manifest
    if id == "serve" {
        return serve::run(args);
    }
    if id == "ckpt" {
        return ckpt::run(args);
    }
    if id == "stream" {
        return stream::run(args);
    }
    if id == "obs" {
        return obs::run(args);
    }
    if id == "coop" {
        return coop::run(args);
    }
    if id == "quant" {
        return quant::run(args);
    }
    if id == "health" {
        return health::run(args);
    }
    if id == "locality" {
        return locality::run(args);
    }
    let mut ctx = Ctx::new()?;
    match id {
        "fig2" => fig2::run(&mut ctx),
        "ablation" => ablation::run(&mut ctx),
        "autotune" => autotune::run(&mut ctx),
        "fig5" => fig5::run(&mut ctx),
        "fig6" => fig67::run_fig6(&mut ctx),
        "fig7" => fig67::run_fig7(&mut ctx),
        "fig8" => fig8::run(&mut ctx),
        "fig9" => fig9::run(&mut ctx),
        "fig10" => fig10::run(&mut ctx),
        "tab3" => tab3::run(&mut ctx),
        "tab4" => tab4::run(&mut ctx),
        "tab5" => tab5::run(&mut ctx),
        "fullbatch" => fullbatch::run(&mut ctx),
        "inference" => inference::run(&mut ctx),
        "preproc" => preproc::run(&mut ctx),
        "all" => {
            for id in [
                "fig5", "fig2", "fig6", "fig7", "fig8", "fig9", "fig10",
                "tab4", "tab5", "fullbatch", "inference", "preproc", "tab3",
            ] {
                println!("\n================ exp {id} ================");
                let a = Args::parse(vec!["exp".into(), id.into()]);
                run_one(&mut ctx, &a)?;
            }
            Ok(())
        }
        other => bail!("unknown experiment id {other:?} (try `comm-rand help`)"),
    }
}

fn run_one(ctx: &mut Ctx, args: &Args) -> Result<()> {
    let id = args.pos.first().map(|s| s.as_str()).unwrap_or("");
    match id {
        "fig2" => fig2::run(ctx),
        "fig5" => fig5::run(ctx),
        "fig6" => fig67::run_fig6(ctx),
        "fig7" => fig67::run_fig7(ctx),
        "fig8" => fig8::run(ctx),
        "fig9" => fig9::run(ctx),
        "fig10" => fig10::run(ctx),
        "tab3" => tab3::run(ctx),
        "tab4" => tab4::run(ctx),
        "tab5" => tab5::run(ctx),
        "fullbatch" => fullbatch::run(ctx),
        "inference" => inference::run(ctx),
        "preproc" => preproc::run(ctx),
        _ => unreachable!(),
    }
}
