//! Cooperative cross-request sampling gate (`comm-rand exp coop`).
//!
//! The paper's thesis — community structure should shape batch
//! composition *and* memory access — becomes a serving-efficiency
//! claim here: at high community bias `p`, co-batched requests hit the
//! same hub neighborhoods, so LABOR-style shared-variate sampling
//! (`sampler=labor`) should (a) report a cross-request `dedup_factor`
//! well above 1 and (b) move strictly fewer feature-gather bytes than
//! independent uniform sampling, at **identical** accuracy (the host
//! executor classifies each root from its precomputed 1-hop
//! aggregation, so logits do not depend on the MFG sampler).
//!
//! For each `p` in the sweep, both samplers serve the *same* workload
//! (same load seed → same request sequence) for several trials;
//! gather-byte and refs/unique totals are summed over trials so a lucky
//! batching pattern in a single run cannot decide the comparison. The
//! gate **fails** unless at every `p ≥` [`GATE_P`]:
//!
//! * labor's aggregate `dedup_factor` > [`MIN_DEDUP`],
//! * labor's total gather bytes < uniform's (strictly),
//! * aggregate accuracy matches uniform's to within 1e-9.
//!
//! `sampler=uniform` stays the serving default, so existing benches
//! are bitwise-identical to pre-knob output; this experiment is where
//! the cooperative path earns its keep. Like `exp serve` it needs no
//! PJRT session, so it gates CI in artifact-less environments, writing
//! `results/coop_bench.{md,json}`.

use anyhow::{bail, Result};

use crate::cli::Args;
use crate::config::preset;
use crate::sampler::SamplerKind;
use crate::serve::{engine, Arrival, LoadConfig, ServeConfig};
use crate::util::json::{num, obj, s, Json};

use super::common::{f2, quick, write_results, Table};

/// Community-bias values swept; the gate applies at `p >= GATE_P`.
const P_SWEEP: [f64; 3] = [0.5, 0.9, 1.0];

/// Bias threshold above which the cooperative win is gated.
pub const GATE_P: f64 = 0.9;

/// Labor must report at least this aggregate dedup factor at gated `p`.
pub const MIN_DEDUP: f64 = 1.2;

/// Per-(p, sampler) totals across trials.
struct ModeTotals {
    sampler: SamplerKind,
    gather_bytes: u64,
    frontier_refs: u64,
    /// Σ unique input nodes (gather_bytes / (feat_dim·4)).
    input_nodes: u64,
    correct: f64,
    evaluated: u64,
    /// Best (lowest) p99 across trials, ms.
    p99_ms: f64,
    /// Best throughput across trials, req/s.
    rps: f64,
}

impl ModeTotals {
    fn dedup(&self) -> f64 {
        if self.input_nodes == 0 {
            1.0
        } else {
            self.frontier_refs as f64 / self.input_nodes as f64
        }
    }

    fn accuracy(&self) -> f64 {
        self.correct / self.evaluated.max(1) as f64
    }
}

pub fn run(args: &Args) -> Result<()> {
    let name = args.pos.get(1).map(String::as_str).unwrap_or("tiny");
    let p = preset(name)
        .ok_or_else(|| anyhow::anyhow!("unknown preset {name}"))?;
    let ds = crate::train::dataset::load_or_build(&p, true)?;

    let mut scfg = ServeConfig::for_dataset(&ds);
    scfg.batch_size = args.get_usize("batch", 32)?;
    // generous coalescing budget: the comparison is about shared
    // neighborhoods, so batches should actually fill
    scfg.max_delay_us = (args.get_f64("delay_ms", 4.0)? * 1e3) as u64;
    scfg.deadline_us = 500_000;
    scfg.workers = args.get_usize("workers", 2)?;
    scfg.seed = args.get_u64("seed", 0)?;
    let lcfg = LoadConfig {
        clients: args.get_usize("clients", 16)?,
        requests_per_client: args
            .get_usize("requests", if quick() { 40 } else { 120 })?,
        zipf_s: args.get_f64("zipf", 1.1)?,
        arrival: Arrival::Closed,
        seed: scfg.seed ^ 0x10AD,
    };
    let trials = args.get_usize("trials", if quick() { 2 } else { 3 })?.max(1);
    let expect = lcfg.clients * lcfg.requests_per_client;
    let (exec, meta) = engine::build_executor(&p, &ds, &scfg)?;

    let mut table = Table::new(&[
        "p",
        "sampler",
        "dedup",
        "gather MB",
        "acc %",
        "p99 ms (best)",
        "req/s (best)",
    ]);
    let mut rows = Vec::new();
    let mut gate_failures: Vec<String> = Vec::new();

    for &bias in &P_SWEEP {
        let mut totals = Vec::new();
        for sampler in [SamplerKind::Uniform, SamplerKind::Labor] {
            let cfg = ServeConfig {
                community_bias: bias,
                sampler,
                ..scfg.clone()
            };
            let mut t = ModeTotals {
                sampler,
                gather_bytes: 0,
                frontier_refs: 0,
                input_nodes: 0,
                correct: 0.0,
                evaluated: 0,
                p99_ms: f64::INFINITY,
                rps: 0.0,
            };
            for trial in 0..trials {
                let l = LoadConfig {
                    seed: lcfg.seed ^ ((trial as u64) << 8),
                    ..lcfg.clone()
                };
                let rep = engine::run(&ds, &meta, exec.as_ref(), &cfg, &l)?;
                println!(
                    "[coop] p={bias:.1} {} trial {trial}: {}",
                    sampler.name(),
                    rep.summary()
                );
                if rep.requests != expect {
                    bail!(
                        "p={bias} sampler={} trial {trial} answered {} of \
                         {expect} requests",
                        sampler.name(),
                        rep.requests,
                    );
                }
                t.gather_bytes += rep.gather_bytes;
                t.frontier_refs += rep.frontier_refs;
                t.input_nodes +=
                    rep.gather_bytes / (ds.feat_dim as u64 * 4);
                t.correct += rep.accuracy * rep.evaluated as f64;
                t.evaluated += rep.evaluated as u64;
                t.p99_ms = t.p99_ms.min(rep.lat_p99_ms);
                t.rps = t.rps.max(rep.throughput_rps);
            }
            table.row(vec![
                format!("{bias:.1}"),
                sampler.name().to_string(),
                format!("{:.2}", t.dedup()),
                format!("{:.2}", t.gather_bytes as f64 / 1e6),
                format!("{:.1}", t.accuracy() * 100.0),
                f2(t.p99_ms),
                format!("{:.0}", t.rps),
            ]);
            rows.push(obj(vec![
                ("p", num(bias)),
                ("sampler", s(sampler.name())),
                ("dedup_factor", num(t.dedup())),
                ("gather_bytes", num(t.gather_bytes as f64)),
                ("frontier_refs", num(t.frontier_refs as f64)),
                ("input_nodes", num(t.input_nodes as f64)),
                ("accuracy", num(t.accuracy())),
                ("p99_ms_best", num(t.p99_ms)),
                ("throughput_rps_best", num(t.rps)),
            ]));
            totals.push(t);
        }

        let (uni, lab) = (&totals[0], &totals[1]);
        debug_assert_eq!(uni.sampler, SamplerKind::Uniform);
        let saved = 1.0 - lab.gather_bytes as f64 / uni.gather_bytes.max(1) as f64;
        println!(
            "[coop] p={bias:.1}: labor dedup x{:.2} (uniform x{:.2}), \
             gather {:.2} MB vs {:.2} MB ({:+.1}% bytes), acc {:.2}% vs \
             {:.2}%",
            lab.dedup(),
            uni.dedup(),
            lab.gather_bytes as f64 / 1e6,
            uni.gather_bytes as f64 / 1e6,
            -saved * 100.0,
            lab.accuracy() * 100.0,
            uni.accuracy() * 100.0,
        );
        if bias >= GATE_P {
            if lab.dedup() <= MIN_DEDUP {
                gate_failures.push(format!(
                    "p={bias}: labor dedup_factor {:.3} <= {MIN_DEDUP}",
                    lab.dedup()
                ));
            }
            if lab.gather_bytes >= uni.gather_bytes {
                gate_failures.push(format!(
                    "p={bias}: labor moved {} gather bytes, uniform {} \
                     (cooperative sampling must move strictly fewer)",
                    lab.gather_bytes, uni.gather_bytes
                ));
            }
            if (lab.accuracy() - uni.accuracy()).abs() > 1e-9 {
                gate_failures.push(format!(
                    "p={bias}: accuracy diverged: labor {:.6} vs uniform \
                     {:.6}",
                    lab.accuracy(),
                    uni.accuracy()
                ));
            }
        }
    }

    if !gate_failures.is_empty() {
        bail!("coop gate failed:\n  {}", gate_failures.join("\n  "));
    }
    println!(
        "[coop] gate ok: at p >= {GATE_P}, cooperative sampling deduped \
         > x{MIN_DEDUP} and moved strictly fewer gather bytes than \
         independent sampling at equal accuracy"
    );

    let md = format!(
        "# Cooperative cross-request sampling ({name})\n\n\
         Closed loop: {} clients x {} requests, batch cap {}, executor \
         `{}`, totals over {} trial(s) per (p, sampler) cell; same load \
         seeds per cell, so both samplers serve the identical request \
         sequence.\n\n{}\n\
         Gate (at p >= {GATE_P}): labor `dedup_factor` > {MIN_DEDUP}, \
         labor gather bytes strictly below uniform's, accuracy equal to \
         1e-9. `sampler=uniform` remains the serving default — existing \
         benches are unchanged; the cooperative path is opt-in via \
         `serve bench sampler=labor`.\n",
        lcfg.clients,
        lcfg.requests_per_client,
        scfg.batch_size,
        exec.name(),
        trials,
        table.to_markdown(),
    );
    let json = obj(vec![
        ("preset", s(name)),
        ("gate_p", num(GATE_P)),
        ("min_dedup", num(MIN_DEDUP)),
        ("trials", num(trials as f64)),
        ("cells", Json::Arr(rows)),
    ]);
    write_results("coop_bench", &md, &json)
}
