//! Table 5 — COMM-RAND generalizes across GNN architectures (§6.4):
//! GCN and GAT on the reddit stand-in, baseline vs the best COMM-RAND
//! knobs; reports accuracy, per-epoch time, epochs, total time.

use anyhow::Result;

use crate::config::{BatchPolicy, TrainConfig};
use crate::train::Method;
use crate::util::json::{num, obj, s, Json};

use super::common::*;

pub fn run(ctx: &mut Ctx) -> Result<()> {
    let cfg = TrainConfig { max_epochs: max_epochs(), ..Default::default() };
    let (p, ds) = ctx.dataset("reddit_sim")?;

    let mut md = String::from(
        "# Table 5 — other GNN models (reddit_sim)\n\n",
    );
    let mut t = Table::new(&[
        "model", "scheme", "val acc %", "per-epoch (ms, modeled)",
        "epochs", "total (ms, modeled)",
    ]);
    let mut jrows = Vec::new();
    for (model, artifact) in [("GCN", "reddit_sim_gcn"), ("GAT", "reddit_sim_gat")] {
        for (mname, pol) in [
            ("Baseline", BatchPolicy::baseline()),
            ("COMM-RAND", best_policy()),
        ] {
            let mut opts_p = p.clone();
            opts_p.artifact = artifact;
            let r = ctx.run(
                &opts_p, &ds, &Method::CommRand(pol.clone()), &cfg, |_| {})?;
            t.row(vec![
                model.into(),
                mname.into(),
                format!("{:.2}", r.best_val_acc * 100.0),
                format!("{:.3}", r.mean_epoch_modeled_s() * 1e3),
                r.converged_epoch.to_string(),
                format!("{:.2}", r.modeled_to_convergence() * 1e3),
            ]);
            jrows.push(obj(vec![
                ("model", s(model)),
                ("scheme", s(mname)),
                ("val_acc", num(r.best_val_acc)),
                ("epoch_modeled_s", num(r.mean_epoch_modeled_s())),
                ("epochs", num(r.converged_epoch as f64)),
                ("total_modeled_s", num(r.modeled_to_convergence())),
            ]));
            println!("[tab5] {model}/{mname} done (acc {:.4})", r.best_val_acc);
        }
    }
    md.push_str(&t.to_markdown());
    write_results("tab5", &md, &Json::Arr(jrows))
}
