//! Table 4 — comparison against prior mini-batching work (§6.3):
//! uniform baseline vs COMM-RAND vs ClusterGCN on all four datasets
//! (per-epoch speedup + val accuracy after a fixed number of epochs),
//! plus the LABOR-0 comparison quoted in the §6.3 text for reddit.
//!
//! Baseline and COMM-RAND run on the community-reordered graph;
//! ClusterGCN (per the paper) is compared against a baseline on the
//! original ordering — here all runs share the reordered graph, which
//! favors ClusterGCN slightly (noted in DESIGN.md).

use anyhow::Result;

use crate::config::{BatchPolicy, TrainConfig};
use crate::train::Method;
use crate::util::json::{num, obj, s, Json};

use super::common::*;

pub fn run(ctx: &mut Ctx) -> Result<()> {
    let epochs = if quick() { 5 } else { 15 }; // paper: 25
    let cfg = TrainConfig {
        max_epochs: epochs,
        patience: usize::MAX, // fixed-epoch protocol
        ..Default::default()
    };
    let datasets = if quick() {
        vec!["reddit_sim", "products_sim"]
    } else {
        vec!["reddit_sim", "igb_sim", "products_sim", "papers_sim"]
    };

    let mut md = format!(
        "# Table 4 — vs ClusterGCN and LABOR ({epochs} epochs)\n\n",
    );
    let mut t = Table::new(&[
        "dataset", "scheme", "per-epoch speedup", "val acc %",
    ]);
    let mut jrows = Vec::new();
    for name in datasets {
        let (p, ds) = ctx.dataset(name)?;
        let methods: Vec<(&str, Method)> = vec![
            ("Baseline", Method::CommRand(BatchPolicy::baseline())),
            ("COMM-RAND", Method::CommRand(best_policy())),
            ("ClusterGCN", Method::ClusterGcn { q: 1 }),
            ("LABOR", Method::Labor),
        ];
        let mut base_epoch = 0.0;
        for (mname, m) in methods {
            let r = ctx.run(&p, &ds, &m, &cfg, |_| {})?;
            let te = r.mean_epoch_modeled_s();
            if mname == "Baseline" {
                base_epoch = te;
            }
            t.row(vec![
                name.into(),
                mname.into(),
                format!("{:.2}x", base_epoch / te),
                format!("{:.2}", r.best_val_acc * 100.0),
            ]);
            jrows.push(obj(vec![
                ("dataset", s(name)),
                ("scheme", s(mname)),
                ("epoch_modeled_s", num(te)),
                ("epoch_speedup", num(base_epoch / te)),
                ("val_acc", num(r.best_val_acc)),
            ]));
            println!("[tab4] {name}/{mname}: {:.2}x, acc {:.4}",
                     base_epoch / te, r.best_val_acc);
        }
    }
    md.push_str(&t.to_markdown());
    md.push_str(
        "\nClusterGCN's per-epoch cost tracks |V| (all partitions each \
         epoch): competitive on large-train-split datasets \
         (reddit/igb), far slower when the training split is small \
         (products/papers). LABOR shrinks the sampled frontier but is \
         community-agnostic, so its speedup stays small.\n",
    );
    write_results("tab4", &md, &Json::Arr(jrows))
}
