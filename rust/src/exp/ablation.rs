//! Ablations for the design choices DESIGN.md calls out (not in the
//! paper; justify this reproduction's substitutions):
//!
//! * **Community granularity** — RABBIT uses cache-scale hierarchy
//!   leaves; we cap Louvain's level at a mean community size. Sweep
//!   the cap and measure modularity, community count, and the
//!   fig10-style per-epoch speedup of MIX-0%+p1.0 vs baseline.
//! * **Cache replay passes** — the L2 model replays each batch's rows
//!   twice (fwd gather + bwd d_w gather). Show 1-pass vs 2-pass miss
//!   rates to document why intra-batch reuse matters for Fig. 10.

use anyhow::Result;

use crate::cachesim::lru::CacheConfig;
use crate::cachesim::SetAssocCache;
use crate::community::louvain::louvain_capped;
use crate::community::community_order;
use crate::config::{preset, BatchPolicy, TrainConfig};
use crate::sampler::RootPolicy;
use crate::train::{self, Method};
use crate::util::json::{num, obj, s, Json};
use crate::util::rng::Rng;

use super::common::*;

pub fn run(ctx: &mut Ctx) -> Result<()> {
    let mut md = String::from("# Ablations (reproduction design choices)\n");
    let mut jout = Vec::new();

    // ---- 1. community granularity ----
    md.push_str("\n## Louvain hierarchy cap (reddit_sim)\n\n");
    let mut t = Table::new(&[
        "mean-size cap", "communities", "modularity Q",
        "MIX-0%+p1.0 epoch speedup",
    ]);
    let p = preset("reddit_sim").unwrap();
    let cfg = TrainConfig { max_epochs: 2, ..Default::default() };
    for cap in [128usize, 512, usize::MAX] {
        // rebuild the dataset with this community granularity
        let mut rng = Rng::new(p.gen_seed);
        let g = crate::graph::gen::generate_sbm(&p.sbm, &mut rng);
        let payload = crate::graph::features::synthesize(
            &g.gt_community, p.sbm.num_comms, &p.feat, &mut rng);
        let det = louvain_capped(&g.csr, p.gen_seed ^ 0x10f2, cap);
        let mut ds = crate::graph::Dataset {
            name: "reddit_sim".into(),
            csr: g.csr,
            features: payload.features,
            feat_dim: p.feat.feat_dim,
            labels: payload.labels,
            num_classes: p.feat.num_classes,
            split: payload.split,
            community: det.community,
            num_comms: det.num_comms,
            gt_community: g.gt_community,
        };
        ds.permute(&community_order(&ds.community));

        let base = ctx.run(&p, &ds,
            &Method::CommRand(BatchPolicy::baseline()), &cfg, |_| {})?;
        let biased = ctx.run(
            &p,
            &ds,
            &Method::CommRand(BatchPolicy {
                roots: RootPolicy::CommRandMix { pct: 0.0 },
                p_intra: 1.0,
            }),
            &cfg,
            |_| {},
        )?;
        let spd = base.mean_epoch_modeled_s() / biased.mean_epoch_modeled_s();
        let cap_label = if cap == usize::MAX {
            "none (top level)".to_string()
        } else {
            cap.to_string()
        };
        t.row(vec![
            cap_label.clone(),
            det.num_comms.to_string(),
            format!("{:.3}", det.modularity),
            format!("{spd:.2}x"),
        ]);
        jout.push(obj(vec![
            ("ablation", s("louvain_cap")),
            ("cap", num(if cap == usize::MAX { -1.0 } else { cap as f64 })),
            ("num_comms", num(det.num_comms as f64)),
            ("modularity", num(det.modularity)),
            ("speedup", num(spd)),
        ]));
        println!("[ablation] louvain cap {cap_label}: {} comms, {spd:.2}x",
                 det.num_comms);
    }
    md.push_str(&t.to_markdown());
    md.push_str(
        "\nCache-scale communities (the RABBIT-style cap) are what make \
         community-pure batches cache-resident; the modularity-maximal \
         top level merges into a handful of giant communities and the \
         locality benefit shrinks.\n",
    );

    // ---- 2. replay passes ----
    md.push_str("\n## L2 replay passes (intra-batch reuse)\n\n");
    let (p, ds) = ctx.dataset("reddit_sim")?;
    let train_nodes = ds.train_nodes();
    let mut rng = Rng::new(5);
    let mut t = Table::new(&["policy", "1-pass miss", "2-pass miss"]);
    for (label, pol) in [
        ("baseline", BatchPolicy::baseline()),
        (
            "MIX-0%+p1.0",
            BatchPolicy { roots: RootPolicy::CommRandMix { pct: 0.0 }, p_intra: 1.0 },
        ),
    ] {
        let order = crate::sampler::roots::order_roots(
            pol.roots, &train_nodes, &ds.community, &mut rng);
        let mut c1 = SetAssocCache::new(CacheConfig::a100_l2(p.l2_base));
        let mut c2 = SetAssocCache::new(CacheConfig::a100_l2(p.l2_base));
        for chunk in order.chunks(256).take(20) {
            let policy = if pol.p_intra <= 0.5 {
                crate::sampler::NeighborPolicy::Uniform
            } else {
                crate::sampler::NeighborPolicy::Biased { p: pol.p_intra }
            };
            let mfg = crate::sampler::build_mfg(
                &ds.csr, &ds.community, chunk, &[5, 10, 10], policy, &mut rng);
            for &v in mfg.input_nodes() {
                c1.access_row(v, ds.feat_dim);
            }
            for _ in 0..2 {
                for &v in mfg.input_nodes() {
                    c2.access_row(v, ds.feat_dim);
                }
            }
        }
        t.row(vec![
            label.into(),
            f4(c1.miss_rate()),
            f4(c2.miss_rate()),
        ]);
        jout.push(obj(vec![
            ("ablation", s("replay_passes")),
            ("policy", s(label)),
            ("miss_1pass", num(c1.miss_rate())),
            ("miss_2pass", num(c2.miss_rate())),
        ]));
    }
    md.push_str(&t.to_markdown());
    md.push_str(
        "\nWith a single pass the model only sees cross-batch reuse; the \
         second (backward) pass is what gives the baseline its \
         at-capacity reuse that the Fig. 10 sweep strips away.\n",
    );

    write_results("ablation", &md, &Json::Arr(jout))
}
