//! Figure 8 — per-epoch time vs training-set size: ClusterGCN visits
//! the whole graph every epoch, so its per-epoch time is invariant to
//! the training split, while the baseline and COMM-RAND shrink with
//! it. Reproduced on the reddit stand-in by artificially subsetting
//! the training set.

use anyhow::Result;

use crate::config::{BatchPolicy, TrainConfig};
use crate::train::Method;
use crate::util::json::{num, obj, s, Json};

use super::common::*;

pub fn run(ctx: &mut Ctx) -> Result<()> {
    let (p, ds) = ctx.dataset("reddit_sim")?;
    let full = ds.train_nodes().len();
    let fractions = [0.1, 0.25, 0.5, 1.0];
    // timing-only runs: 2 epochs, no early-stop interference
    let cfg = TrainConfig { max_epochs: 2, ..Default::default() };

    let methods: Vec<(&str, Method)> = vec![
        ("baseline", Method::CommRand(BatchPolicy::baseline())),
        ("COMM-RAND", Method::CommRand(best_policy())),
        ("ClusterGCN", Method::ClusterGcn { q: 1 }),
    ];

    let mut md = String::from(
        "# Figure 8 — per-epoch time vs training-set size (reddit_sim)\n\n",
    );
    let mut t = Table::new(&[
        "train size", "baseline (ms)", "COMM-RAND (ms)", "ClusterGCN (ms)",
    ]);
    let mut jrows = Vec::new();
    for frac in fractions {
        let subset = ((full as f64) * frac) as usize;
        let mut cells = vec![format!("{subset} ({:.0}%)", frac * 100.0)];
        let mut jcells = vec![("train_size", num(subset as f64))];
        for (mname, m) in &methods {
            let r = ctx.run(&p, &ds, m, &cfg, |o| {
                o.train_subset = Some(subset);
            })?;
            let ms = r.mean_epoch_modeled_s() * 1e3;
            cells.push(format!("{ms:.3}"));
            jcells.push((
                match *mname {
                    "baseline" => "baseline_ms",
                    "COMM-RAND" => "commrand_ms",
                    _ => "clustergcn_ms",
                },
                num(ms),
            ));
        }
        t.row(cells);
        jrows.push(obj(jcells.into_iter().map(|(k, v)| (k, v)).collect()));
        println!("[fig8] train={:.0}% done", frac * 100.0);
    }
    md.push_str(&t.to_markdown());
    md.push_str(
        "\nClusterGCN's per-epoch time is ~constant across training-set \
         sizes (it trains on every partition of the graph each epoch); \
         the baseline and COMM-RAND scale with the training set.\n",
    );
    let json = Json::Arr(jrows);
    let _ = s("x");
    write_results("fig8", &md, &json)
}
