//! Observability overhead gate + trace validation (`comm-rand exp
//! obs`).
//!
//! Tracing that distorts the thing it measures is worse than no
//! tracing, so this experiment runs the same closed-loop serve bench
//! three ways — tracing off, sampled (100 ‰ of request ids), and full
//! rate (1000 ‰) — and **fails** if full-rate tracing costs more than
//! [`MAX_OVERHEAD_FRAC`] of untraced throughput. Each mode takes the
//! best of several trials so a scheduler hiccup cannot flunk the gate.
//!
//! It then re-parses the full-rate Chrome trace and checks it is a
//! usable artifact, not just a nonempty file: sample / gather /
//! execute spans present on the shard tracks, gather spans tagged
//! with cache hit/stale/miss counts, coalesce spans carrying the
//! community-purity counters, and the ring-drop count accounted for
//! in the file's metadata.
//!
//! Like `exp serve` this needs no PJRT session (host-executor
//! fallback), so it runs — and gates CI — in artifact-less
//! environments.

use anyhow::{bail, Result};

use crate::cli::Args;
use crate::config::preset;
use crate::serve::{engine, Arrival, LoadConfig, ServeConfig};
use crate::util::json::{num, obj, s, Json};

use super::common::{f2, quick, results_dir, write_results, Table};

/// Full-rate tracing may cost at most this fraction of untraced
/// throughput (the ≤ 5 % acceptance bar).
pub const MAX_OVERHEAD_FRAC: f64 = 0.05;

struct Mode {
    label: &'static str,
    /// `None` = tracing off; `Some(permille)` = trace at that rate.
    sample: Option<u32>,
}

pub fn run(args: &Args) -> Result<()> {
    let name = args.pos.get(1).map(String::as_str).unwrap_or("tiny");
    let p = preset(name)
        .ok_or_else(|| anyhow::anyhow!("unknown preset {name}"))?;
    let ds = crate::train::dataset::load_or_build(&p, true)?;

    let mut scfg = ServeConfig::for_dataset(&ds);
    scfg.batch_size = args.get_usize("batch", 32)?;
    scfg.workers = args.get_usize("workers", scfg.workers)?;
    scfg.shards = args.get_usize("shards", 2)?;
    scfg.seed = args.get_u64("seed", 0)?;
    let lcfg = LoadConfig {
        clients: args.get_usize("clients", 4)?,
        requests_per_client: args
            .get_usize("requests", if quick() { 50 } else { 200 })?,
        zipf_s: args.get_f64("zipf", 1.1)?,
        arrival: Arrival::Closed,
        seed: scfg.seed ^ 0x10AD,
    };
    let trials = args.get_usize("trials", if quick() { 2 } else { 3 })?.max(1);
    let (exec, meta) = engine::build_executor(&p, &ds, &scfg)?;

    let trace_path = results_dir().join("obs_trace.json");
    let modes = [
        Mode { label: "off", sample: None },
        Mode { label: "sampled", sample: Some(100) },
        Mode { label: "full", sample: Some(1000) },
    ];

    let mut table = Table::new(&[
        "mode",
        "sample ‰",
        "req/s (best)",
        "p50 ms",
        "p99 ms",
        "overhead",
    ]);
    let mut rows = Vec::new();
    let mut best = [0.0f64; 3];
    for (mi, mode) in modes.iter().enumerate() {
        let cfg = ServeConfig {
            trace: mode.sample.map(|_| trace_path.clone()),
            trace_sample: mode.sample.unwrap_or(1000),
            ..scfg.clone()
        };
        let mut best_rep = None;
        for t in 0..trials {
            let l = LoadConfig { seed: lcfg.seed ^ t as u64, ..lcfg.clone() };
            let rep = engine::run(&ds, &meta, exec.as_ref(), &cfg, &l)?;
            println!("[obs] {} trial {}: {}", mode.label, t, rep.summary());
            if rep.requests != lcfg.clients * lcfg.requests_per_client {
                bail!(
                    "mode {} answered {} of {} requests",
                    mode.label,
                    rep.requests,
                    lcfg.clients * lcfg.requests_per_client
                );
            }
            if rep.throughput_rps > best[mi] {
                best[mi] = rep.throughput_rps;
                best_rep = Some(rep);
            }
        }
        let rep = best_rep.expect("at least one trial ran");
        let overhead = 1.0 - best[mi] / best[0].max(1e-9);
        table.row(vec![
            mode.label.to_string(),
            mode.sample.map(|s| s.to_string()).unwrap_or("-".into()),
            format!("{:.0}", best[mi]),
            f2(rep.lat_p50_ms),
            f2(rep.lat_p99_ms),
            if mi == 0 {
                "-".to_string()
            } else {
                format!("{:+.1}%", overhead * 100.0)
            },
        ]);
        rows.push(obj(vec![
            ("mode", s(mode.label)),
            (
                "sample_permille",
                num(mode.sample.map(|v| v as f64).unwrap_or(0.0)),
            ),
            ("throughput_rps", num(best[mi])),
            ("overhead_frac", num(if mi == 0 { 0.0 } else { overhead })),
            ("report", rep.to_json()),
        ]));
    }

    // ---- the overhead gate ----
    let overhead = 1.0 - best[2] / best[0].max(1e-9);
    println!(
        "[obs] full-rate tracing overhead: {:+.2}% of untraced throughput \
         ({:.0} -> {:.0} req/s, gate {:.0}%)",
        overhead * 100.0,
        best[0],
        best[2],
        MAX_OVERHEAD_FRAC * 100.0
    );
    if overhead > MAX_OVERHEAD_FRAC {
        bail!(
            "full-rate tracing costs {:.1}% throughput (> {:.0}% budget): \
             {:.0} req/s untraced vs {:.0} req/s traced",
            overhead * 100.0,
            MAX_OVERHEAD_FRAC * 100.0,
            best[0],
            best[2]
        );
    }

    // ---- trace validation (the last full-rate run's export) ----
    let checks = validate_trace(&trace_path)?;
    println!(
        "[obs] trace ok: {} spans ({} sample / {} gather / {} execute), \
         {} coalesce with purity tags, {} dropped",
        checks.spans,
        checks.sample,
        checks.gather,
        checks.execute,
        checks.coalesce,
        checks.dropped
    );

    let md = format!(
        "# Observability overhead gate ({name})\n\n\
         Closed loop: {} clients x {} requests, batch cap {}, {} shards, \
         executor `{}`, best of {} trial(s) per mode.\n\n{}\n\
         Full-rate tracing overhead {:+.2}% (budget {:.0}%). The full-rate \
         Chrome trace at `results/obs_trace.json` carries {} spans \
         ({} sample / {} gather / {} execute); every gather span is tagged \
         with cache hit/stale/miss counts and every coalesce span with the \
         micro-batch's community purity. {} events were dropped to ring \
         wraparound (accounted in the trace metadata).\n",
        lcfg.clients,
        lcfg.requests_per_client,
        scfg.batch_size,
        scfg.shards,
        exec.name(),
        trials,
        table.to_markdown(),
        overhead * 100.0,
        MAX_OVERHEAD_FRAC * 100.0,
        checks.spans,
        checks.sample,
        checks.gather,
        checks.execute,
        checks.dropped
    );
    let json = obj(vec![
        ("modes", Json::Arr(rows)),
        ("overhead_frac", num(overhead)),
        ("overhead_budget_frac", num(MAX_OVERHEAD_FRAC)),
        ("trace_spans", num(checks.spans as f64)),
        ("trace_dropped", num(checks.dropped as f64)),
    ]);
    write_results("obs", &md, &json)
}

struct TraceChecks {
    spans: usize,
    sample: usize,
    gather: usize,
    execute: usize,
    coalesce: usize,
    dropped: usize,
}

/// Re-parse an exported Chrome trace and verify it is the artifact the
/// docs promise: per-request pipeline spans with their counter tags.
fn validate_trace(path: &std::path::Path) -> Result<TraceChecks> {
    let doc = Json::parse_file(path)?;
    let events = doc.get("traceEvents")?.as_arr()?;
    let mut c = TraceChecks {
        spans: 0,
        sample: 0,
        gather: 0,
        execute: 0,
        coalesce: 0,
        dropped: doc.get("otherData")?.get("dropped_events")?.as_usize()?,
    };
    for ev in events {
        let ph = ev.get("ph")?.as_str()?;
        if ph != "X" {
            continue;
        }
        c.spans += 1;
        let name = ev.get("name")?.as_str()?;
        let args = ev.get("args")?;
        match name {
            "sample" => {
                c.sample += 1;
                args.get("overlap_permille")?.as_usize()?;
            }
            "gather" => {
                c.gather += 1;
                for tag in ["hits", "misses", "stale"] {
                    args.get(tag)?.as_usize()?;
                }
            }
            "execute" => c.execute += 1,
            "coalesce" => {
                c.coalesce += 1;
                let purity = args.get("purity_permille")?.as_usize()?;
                if purity > 1000 {
                    bail!("coalesce purity {purity} out of permille range");
                }
                args.get("communities")?.as_usize()?;
            }
            _ => {}
        }
    }
    if c.spans == 0 {
        bail!("trace at {} has no spans", path.display());
    }
    for (what, n) in [
        ("sample", c.sample),
        ("gather", c.gather),
        ("execute", c.execute),
        ("coalesce", c.coalesce),
    ] {
        if n == 0 {
            bail!("trace at {} has no {what} spans", path.display());
        }
    }
    Ok(c)
}
