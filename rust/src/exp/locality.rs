//! Locality-observatory gate (`comm-rand exp locality`): prove the
//! reuse-distance profiler measures the quantity the paper's batching
//! policy actually changes — and that measuring it is nearly free.
//!
//! The paper's claim is structural: community-aware batching shortens
//! reuse distances in the feature gather, which is *why* caches work
//! harder at `p = 1`. A profiler that cannot resolve that shift, or
//! whose miss-ratio-curve predictions disagree with the live cache it
//! sits next to, is decoration. This experiment drives the same bench
//! through three phases and **fails** unless all gates hold:
//!
//! 1. **Sweep** — closed loop at `p ∈ {0, 0.5, 1}` with the profiler
//!    at full sampling: mean reuse distance must *strictly* shrink as
//!    `p` rises and the MRC-predicted miss ratio at the current cache
//!    size must fall with it, at equal accuracy ([`ACC_TOLERANCE`],
//!    checked when the executor reports real logits). At every point
//!    the advisor's predicted hit rate must land within
//!    [`MAX_ADVISOR_ERR`] of the live cache's observed rate, and the
//!    merged MRC must be monotone non-increasing in capacity.
//! 2. **Trace** — the `p = 1` leg runs with `health_ms=` + `trace=`
//!    armed: every sealed health window must land a `locality`
//!    counter sample in the Chrome trace (mean distance, predicted
//!    miss permille, self-reuse permille as counter series).
//! 3. **Overhead** — best-of-N closed-loop trials with the profiler
//!    off vs on at full sampling: `locality=1` may cost at most
//!    [`MAX_OVERHEAD_FRAC`] of baseline throughput.
//!
//! Like `exp serve` / `exp health` this needs no PJRT session, so it
//! runs — and gates CI — in artifact-less environments.

use anyhow::{bail, Result};

use crate::cli::Args;
use crate::config::preset;
use crate::serve::{engine, Arrival, LoadConfig, ServeConfig};
use crate::util::json::{num, obj, s, Json};

use super::common::{f2, pct, quick, results_dir, write_results, Table};
use super::health::count_trace_events;

/// Enabling the profiler at full sampling may cost at most this
/// fraction of profiler-off throughput (the ≤ 5 % acceptance bar).
pub const MAX_OVERHEAD_FRAC: f64 = 0.05;

/// The advisor's MRC-predicted hit rate must land within this many
/// points of the live cache's observed hit rate at every sweep point.
pub const MAX_ADVISOR_ERR: f64 = 0.05;

/// The bias knob regroups requests, it does not change what is
/// computed: top-1 accuracy across the sweep may spread at most this
/// much (gated only when the executor reports real logits).
pub const ACC_TOLERANCE: f64 = 0.02;

pub fn run(args: &Args) -> Result<()> {
    let name = args.pos.get(1).map(String::as_str).unwrap_or("tiny");
    let p = preset(name)
        .ok_or_else(|| anyhow::anyhow!("unknown preset {name}"))?;
    let ds = crate::train::dataset::load_or_build(&p, true)?;

    let mut base = ServeConfig::for_dataset(&ds);
    base.batch_size = args.get_usize("batch", 32)?;
    base.workers = args.get_usize("workers", base.workers)?;
    base.seed = args.get_u64("seed", 0)?;
    let permille = args.get_u64("locality_sample", 1000)? as u32;
    if permille == 0 || permille > 1000 {
        bail!("locality_sample is permille in [1, 1000], got {permille}");
    }
    base.locality = true;
    base.locality_sample = permille;
    base.mrc_points = args.get_usize("mrc_points", 16)?.max(1);
    let trials =
        args.get_usize("trials", if quick() { 2 } else { 3 })?.max(1);
    let closed = LoadConfig {
        clients: args.get_usize("clients", 4)?,
        requests_per_client: args
            .get_usize("requests", if quick() { 80 } else { 240 })?,
        zipf_s: args.get_f64("zipf", 1.1)?,
        arrival: Arrival::Closed,
        seed: base.seed ^ 0x10AD,
    };
    let (exec, meta) = engine::build_executor(&p, &ds, &base)?;

    // ---- phase 1+2: the bias sweep (trace armed on the p=1 leg) ----
    let trace_path = results_dir().join("locality_trace.json");
    let mut table = Table::new(&[
        "p",
        "req/s",
        "acc",
        "cache hit",
        "dist rows",
        "p95 rows",
        "self reuse",
        "pred miss",
        "advisor err",
    ]);
    let mut sweep_rows = Vec::new();
    let mut dists = Vec::new();
    let mut pred_misses = Vec::new();
    let mut accs = Vec::new();
    let mut evaluated_everywhere = true;
    let mut advisor_err_max = 0.0f64;
    for bias in [0.0, 0.5, 1.0] {
        let last = bias == 1.0;
        let cfg = ServeConfig {
            community_bias: bias,
            // the p=1 leg doubles as the trace gate: seal health
            // windows so the telemetry thread emits `locality`
            // counter samples into the Chrome trace
            health_ms: if last { 5 } else { 0 },
            trace: last.then(|| trace_path.clone()),
            trace_sample: 1000,
            ..base.clone()
        };
        let rep = engine::run(&ds, &meta, exec.as_ref(), &cfg, &closed)?;
        println!("[locality] p={bias}: {}", rep.summary());
        if rep.errors > 0 {
            bail!("p={bias} run had {} errors", rep.errors);
        }
        let loc = rep.locality.as_ref().ok_or_else(|| {
            anyhow::anyhow!("locality=1 run at p={bias} reported no profile")
        })?;
        if loc.sample_permille != permille {
            bail!(
                "profiler ran at {}‰, asked for {permille}‰",
                loc.sample_permille
            );
        }
        if loc.accesses == 0 || loc.sampled == 0 || loc.reuses == 0 {
            bail!(
                "p={bias} profile is empty: {} accesses, {} sampled, {} \
                 reuses",
                loc.accesses,
                loc.sampled,
                loc.reuses
            );
        }
        // the MRC must be a curve: capacities rising, predicted miss
        // ratio monotone non-increasing (more cache never misses more)
        for w in loc.mrc.windows(2) {
            if w[0].capacity_rows >= w[1].capacity_rows
                || w[1].miss_ratio > w[0].miss_ratio + 1e-12
            {
                bail!(
                    "non-monotone MRC at p={bias}: ({}, {:.4}) -> ({}, \
                     {:.4})",
                    w[0].capacity_rows,
                    w[0].miss_ratio,
                    w[1].capacity_rows,
                    w[1].miss_ratio
                );
            }
        }
        let err = (loc.predicted_hit_rate - loc.observed_hit_rate).abs();
        advisor_err_max = advisor_err_max.max(err);
        if err > MAX_ADVISOR_ERR {
            bail!(
                "advisor off by {:.1} points at p={bias} (predicted \
                 {:.1}%, observed {:.1}%, budget {:.0} points)",
                err * 100.0,
                loc.predicted_hit_rate * 100.0,
                loc.observed_hit_rate * 100.0,
                MAX_ADVISOR_ERR * 100.0
            );
        }
        let pred_miss = 1.0 - loc.predicted_hit_rate;
        table.row(vec![
            f2(bias),
            format!("{:.0}", rep.throughput_rps),
            if rep.evaluated > 0 { pct(rep.accuracy) } else { "-".into() },
            pct(rep.cache_hit_rate),
            format!("{:.0}", loc.mean_reuse_distance),
            format!("{}", loc.p95_reuse_distance),
            pct(loc.self_reuse_frac),
            pct(pred_miss),
            format!("{:.3}", err),
        ]);
        dists.push(loc.mean_reuse_distance);
        pred_misses.push(pred_miss);
        accs.push(rep.accuracy);
        evaluated_everywhere &= rep.evaluated > 0;
        sweep_rows.push(rep.to_json());
    }

    // the trend gate: community bias must strictly shorten reuse
    // distance and the predicted miss ratio must fall with it
    for i in 1..dists.len() {
        if dists[i] >= dists[i - 1] {
            bail!(
                "mean reuse distance did not shrink: {:.1} rows at \
                 p-point {} vs {:.1} at {} (the knob is not buying \
                 locality)",
                dists[i],
                i,
                dists[i - 1],
                i - 1
            );
        }
        if pred_misses[i] >= pred_misses[i - 1] {
            bail!(
                "MRC-predicted miss ratio did not fall: {:.4} at \
                 p-point {} vs {:.4} at {}",
                pred_misses[i],
                i,
                pred_misses[i - 1],
                i - 1
            );
        }
    }
    let acc_spread = accs.iter().cloned().fold(f64::MIN, f64::max)
        - accs.iter().cloned().fold(f64::MAX, f64::min);
    if evaluated_everywhere && acc_spread > ACC_TOLERANCE {
        bail!(
            "accuracy moved {:.1} points across the sweep (> {:.0} \
             allowed): batching must not change what is computed",
            acc_spread * 100.0,
            ACC_TOLERANCE * 100.0
        );
    }
    println!(
        "[locality] trend ok: dist {:.0} -> {:.0} -> {:.0} rows, \
         predicted miss {:.1}% -> {:.1}% -> {:.1}%, advisor err max \
         {:.3}",
        dists[0],
        dists[1],
        dists[2],
        pred_misses[0] * 100.0,
        pred_misses[1] * 100.0,
        pred_misses[2] * 100.0,
        advisor_err_max
    );

    // the trace gate: sealed windows became counter samples
    let loc_events = count_trace_events(&trace_path, "locality")?;
    if loc_events == 0 {
        bail!(
            "trace at {} carries no locality counter samples",
            trace_path.display()
        );
    }
    println!("[locality] trace ok: {loc_events} counter sample(s)");

    // ---- phase 3: the overhead gate ----
    let off_cfg = ServeConfig { locality: false, ..base.clone() };
    let mut best_off = 0.0f64;
    let mut best_on = 0.0f64;
    for t in 0..trials {
        let l = LoadConfig { seed: closed.seed ^ t as u64, ..closed.clone() };
        let off = engine::run(&ds, &meta, exec.as_ref(), &off_cfg, &l)?;
        let on = engine::run(&ds, &meta, exec.as_ref(), &base, &l)?;
        println!(
            "[locality] overhead trial {t}: off {:.0} req/s, on {:.0} \
             req/s",
            off.throughput_rps, on.throughput_rps
        );
        best_off = best_off.max(off.throughput_rps);
        best_on = best_on.max(on.throughput_rps);
    }
    let overhead = 1.0 - best_on / best_off.max(1e-9);
    println!(
        "[locality] profiler overhead: {:+.2}% of baseline throughput \
         ({:.0} -> {:.0} req/s, gate {:.0}%)",
        overhead * 100.0,
        best_off,
        best_on,
        MAX_OVERHEAD_FRAC * 100.0
    );
    if overhead > MAX_OVERHEAD_FRAC {
        bail!(
            "profiler costs {:.1}% throughput (> {:.0}% budget): {:.0} \
             req/s off vs {:.0} req/s on",
            overhead * 100.0,
            MAX_OVERHEAD_FRAC * 100.0,
            best_off,
            best_on
        );
    }

    let md = format!(
        "# Locality-observatory gate ({name})\n\n\
         Closed loop: {} clients x {} requests, zipf {}, executor `{}`, \
         profiler at {permille}\u{2030} sampling, {} MRC points. \
         Sweeping the community-bias knob strictly shortened the mean \
         gather reuse distance ({:.0} -> {:.0} -> {:.0} rows) and the \
         MRC-predicted miss ratio ({:.1}% -> {:.1}% -> {:.1}%); the \
         advisor's prediction stayed within {:.3} of the live cache's \
         observed hit rate (budget {:.2}){}. The p=1 leg exported {} \
         `locality` counter sample(s) to the Chrome trace. Profiler \
         overhead {:+.2}% (budget {:.0}%), best of {} trial(s).\n\n{}\n",
        closed.clients,
        closed.requests_per_client,
        closed.zipf_s,
        exec.name(),
        base.mrc_points,
        dists[0],
        dists[1],
        dists[2],
        pred_misses[0] * 100.0,
        pred_misses[1] * 100.0,
        pred_misses[2] * 100.0,
        advisor_err_max,
        MAX_ADVISOR_ERR,
        if evaluated_everywhere {
            format!(", accuracy spread {:.3}", acc_spread)
        } else {
            " (accuracy ungated: no-op executor)".to_string()
        },
        loc_events,
        overhead * 100.0,
        MAX_OVERHEAD_FRAC * 100.0,
        trials,
        table.to_markdown()
    );
    let json = obj(vec![
        ("preset", s(name)),
        ("sample_permille", num(permille as f64)),
        ("mrc_points", num(base.mrc_points as f64)),
        ("sweep", Json::Arr(sweep_rows)),
        ("mean_reuse_distance", Json::Arr(dists.iter().map(|d| num(*d)).collect())),
        (
            "predicted_miss",
            Json::Arr(pred_misses.iter().map(|m| num(*m)).collect()),
        ),
        ("advisor_err_max", num(advisor_err_max)),
        ("advisor_err_budget", num(MAX_ADVISOR_ERR)),
        ("accuracy_gated", Json::Bool(evaluated_everywhere)),
        ("accuracy_spread", num(acc_spread)),
        ("locality_trace_events", num(loc_events as f64)),
        ("overhead_frac", num(overhead)),
        ("overhead_budget_frac", num(MAX_OVERHEAD_FRAC)),
    ]);
    write_results("locality_bench", &md, &json)
}
