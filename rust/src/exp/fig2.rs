//! Figure 2 — the motivating extreme: entirely community-based
//! mini-batching (NORAND-ROOTS & p=1.0) vs uniform random, on the
//! reddit and papers100M stand-ins. Reports the validation-accuracy
//! trajectory and the per-epoch / epochs / total-time trade-off that
//! motivates COMM-RAND.

use anyhow::Result;

use crate::config::{BatchPolicy, TrainConfig};
use crate::sampler::RootPolicy;
use crate::train::Method;
use crate::util::json::{arr_f64, num, obj, s, Json};

use super::common::*;

pub fn run(ctx: &mut Ctx) -> Result<()> {
    let cfg = TrainConfig { max_epochs: max_epochs(), ..Default::default() };
    let datasets = if quick() {
        vec!["reddit_sim"]
    } else {
        vec!["papers_sim", "reddit_sim"]
    };
    let mut md = String::from(
        "# Figure 2 — cost of eliminating randomization entirely\n\n",
    );
    let mut jout = Vec::new();
    for name in datasets {
        let (p, ds) = ctx.dataset(name)?;
        let base = ctx.run_seeds(
            &p, &ds, &Method::CommRand(BatchPolicy::baseline()), &cfg)?;
        let pure = ctx.run_seeds(
            &p,
            &ds,
            &Method::CommRand(BatchPolicy {
                roots: RootPolicy::NoRand,
                p_intra: 1.0,
            }),
            &cfg,
        )?;
        let (ab, ap) = (aggregate(&base), aggregate(&pure));
        md.push_str(&format!("\n## {name}\n\n"));
        let mut t = Table::new(&[
            "scheme", "val acc", "per-epoch speedup", "epochs ratio",
            "net training speedup",
        ]);
        t.row(vec![
            "uniform random".into(),
            f4(ab.val_acc),
            "1.00x".into(),
            "1.00".into(),
            "1.00x".into(),
        ]);
        t.row(vec![
            "entirely community-based".into(),
            f4(ap.val_acc),
            format!("{:.2}x", ab.epoch_modeled_s / ap.epoch_modeled_s),
            f2(ap.converged_epochs / ab.converged_epochs),
            format!("{:.2}x", ab.total_modeled_s / ap.total_modeled_s),
        ]);
        md.push_str(&t.to_markdown());
        md.push_str(&format!(
            "\naccuracy delta: {:.2} pts\n",
            (ab.val_acc - ap.val_acc) * 100.0
        ));
        jout.push(obj(vec![
            ("dataset", s(name)),
            ("baseline_acc", num(ab.val_acc)),
            ("pure_acc", num(ap.val_acc)),
            (
                "baseline_curve",
                arr_f64(
                    &base[0].epochs.iter().map(|e| e.val_acc).collect::<Vec<_>>(),
                ),
            ),
            (
                "pure_curve",
                arr_f64(
                    &pure[0].epochs.iter().map(|e| e.val_acc).collect::<Vec<_>>(),
                ),
            ),
            ("epoch_speedup", num(ab.epoch_modeled_s / ap.epoch_modeled_s)),
            ("epochs_ratio", num(ap.converged_epochs / ab.converged_epochs)),
            ("net_speedup", num(ab.total_modeled_s / ap.total_modeled_s)),
        ]));
    }
    write_results("fig2", &md, &Json::Arr(jout))
}
