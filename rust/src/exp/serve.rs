//! Serving-knob sweeps: the online-inference analogue of the paper's
//! training figures. Replays the same Zipf trace against the serving
//! engine along three axes:
//!
//! * community-bias `p ∈ {0, 0.5, 1}` on one shard — the knob's effect
//!   on throughput, tail latency, feature-cache hit rate and mean
//!   gather reuse distance (closed loop; the locality observatory is
//!   armed on every axis, and `exp locality` gates the trend);
//! * shard count `∈ {1, 2, 4}` at fixed `p` — community-affinity
//!   scaling: each shard's cache only sees its own communities, so the
//!   aggregate hit rate should hold (or improve) as the per-shard
//!   cache slice shrinks (closed loop);
//! * offered load × admission policy — open-loop Poisson arrivals
//!   swept past saturation, `admission ∈ {none, reject}`: with `none`
//!   the p99 latency diverges with the backlog (the latency cliff, at
//!   best clipped by queue-full drop-tail); with `reject` unmeetable
//!   requests are shed at enqueue, so p99 stays bounded and the
//!   shed-rate column shows the price.
//!
//! Unlike the training experiments this needs no PJRT session: it uses
//! the compiled infer artifact when available and the no-op executor
//! otherwise, so `comm-rand exp serve` runs in artifact-less
//! environments too.

use anyhow::{Context, Result};

use crate::cli::Args;
use crate::config::preset;
use crate::serve::{
    engine, AdmissionPolicy, Arrival, LoadConfig, ServeConfig, SpillPolicy,
};
use crate::util::json::{obj, Json};

use super::common::{f2, pct, quick, write_results, Table};

pub fn run(args: &Args) -> Result<()> {
    let name = args.pos.get(1).map(String::as_str).unwrap_or("tiny");
    let p = preset(name)
        .ok_or_else(|| anyhow::anyhow!("unknown preset {name}"))?;
    let ds = crate::train::dataset::load_or_build(&p, true)?;

    let mut scfg = ServeConfig::for_dataset(&ds);
    scfg.batch_size = args.get_usize("batch", 32)?;
    scfg.seed = args.get_u64("seed", 0)?;
    // profile gather locality across every axis (the p-sweep table
    // shows the mean reuse distance the bias knob is buying; `exp
    // locality` gates the trend and the profiler's own overhead)
    scfg.locality = true;
    let spill = SpillPolicy::parse(args.get("spill").unwrap_or("strict"))?;
    let lcfg = LoadConfig {
        clients: args.get_usize("clients", 8)?,
        requests_per_client: args
            .get_usize("requests", if quick() { 40 } else { 200 })?,
        zipf_s: args.get_f64("zipf", 1.1)?,
        arrival: Arrival::Closed,
        seed: scfg.seed ^ 0x10AD,
    };
    let (exec, meta) = engine::build_executor(&p, &ds, &scfg)?;

    // axis 1: community-bias knob on a single shard
    let mut p_table = Table::new(&[
        "p",
        "req/s",
        "p50 ms",
        "p95 ms",
        "p99 ms",
        "cache hit",
        "dist rows",
        "req/batch",
    ]);
    let shard_p = args.get_f64("shard_p", 1.0)?;
    if !(0.0..=1.0).contains(&shard_p) {
        anyhow::bail!("shard_p must be in [0, 1], got {shard_p}");
    }
    let mut p_rows = Vec::new();
    // the p-sweep row matching (shard_p, 1 shard, default spill) doubles
    // as the shard sweep's baseline, so that config isn't re-run below
    let mut one_shard_baseline = None;
    for bias in [0.0, 0.5, 1.0] {
        let cfg = ServeConfig { community_bias: bias, ..scfg.clone() };
        let rep = engine::run(&ds, &meta, exec.as_ref(), &cfg, &lcfg)?;
        println!("{}", rep.summary());
        p_table.row(vec![
            f2(bias),
            format!("{:.0}", rep.throughput_rps),
            f2(rep.lat_p50_ms),
            f2(rep.lat_p95_ms),
            f2(rep.lat_p99_ms),
            pct(rep.cache_hit_rate),
            rep.locality
                .as_ref()
                .map(|l| format!("{:.0}", l.mean_reuse_distance))
                .unwrap_or_else(|| "-".into()),
            f2(rep.mean_batch_size),
        ]);
        p_rows.push(rep.to_json());
        if bias == shard_p && scfg.shards == 1 && spill == scfg.spill {
            one_shard_baseline = Some(rep);
        }
    }

    // axis 2: shard count at fixed p (community affinity across
    // logical devices, `spill=` selects the cross-shard policy)
    let mut s_table = Table::new(&[
        "shards",
        "spill",
        "req/s",
        "p50 ms",
        "p99 ms",
        "cache hit",
        "foreign",
        "depth max",
    ]);
    let mut s_rows = Vec::new();
    for n_shards in [1usize, 2, 4] {
        let rep = match (n_shards, one_shard_baseline.take()) {
            (1, Some(baseline)) => baseline, // identical config: reuse
            _ => {
                let cfg = ServeConfig {
                    community_bias: shard_p,
                    shards: n_shards,
                    spill,
                    ..scfg.clone()
                };
                let rep = engine::run(&ds, &meta, exec.as_ref(), &cfg, &lcfg)?;
                println!("{}", rep.summary());
                rep
            }
        };
        let depth_max =
            rep.shards.iter().map(|sh| sh.queue_depth_max).max().unwrap_or(0);
        s_table.row(vec![
            format!("{n_shards}"),
            spill.name().to_string(),
            format!("{:.0}", rep.throughput_rps),
            f2(rep.lat_p50_ms),
            f2(rep.lat_p99_ms),
            pct(rep.cache_hit_rate),
            format!("{}", rep.foreign_requests()),
            format!("{depth_max}"),
        ]);
        s_rows.push(rep.to_json());
    }

    // axis 3: offered load x admission policy (open-loop Poisson).
    // The sweep deliberately crosses the saturation rate: closed-loop
    // throughput above tells us roughly where it is, and the top rates
    // sit well past it, so the `none` rows show the latency cliff and
    // the `reject` rows show it clipped (nonzero shed-rate instead).
    let rates: Vec<f64> = match args.get("rates") {
        Some(spec) => spec
            .split(',')
            .map(|v| v.trim().parse::<f64>().context("bad rates= value"))
            .collect::<Result<Vec<f64>>>()?,
        None if quick() => vec![2_000.0, 16_000.0],
        None => vec![2_000.0, 8_000.0, 32_000.0, 128_000.0],
    };
    // same validity rule Arrival::parse enforces on the CLI path — a
    // zero/negative/NaN rate would make the open-loop clients sleep
    // (near) forever instead of erroring
    for &r in &rates {
        if !(r.is_finite() && r > 0.0) {
            anyhow::bail!("rates= values must be positive numbers, got {r}");
        }
    }
    let mut a_table = Table::new(&[
        "rate rps",
        "admission",
        "done",
        "done rps",
        "p50 ms",
        "p99 ms",
        "shed rate",
        "degraded",
    ]);
    let mut a_rows = Vec::new();
    for &rate in &rates {
        for adm in [AdmissionPolicy::None, AdmissionPolicy::Reject] {
            let cfg = ServeConfig {
                community_bias: shard_p,
                admission: adm,
                ..scfg.clone()
            };
            let l = LoadConfig {
                arrival: Arrival::Poisson { rate_rps: rate },
                ..lcfg.clone()
            };
            let rep = engine::run(&ds, &meta, exec.as_ref(), &cfg, &l)?;
            println!("{}", rep.summary());
            a_table.row(vec![
                format!("{rate:.0}"),
                adm.name().to_string(),
                format!("{}", rep.requests),
                format!("{:.0}", rep.throughput_rps),
                f2(rep.lat_p50_ms),
                f2(rep.lat_p99_ms),
                pct(rep.shed_rate),
                format!("{}", rep.degraded),
            ]);
            a_rows.push(rep.to_json());
        }
    }

    // axis 4: hot swap under load. Only meaningful on the host
    // executor (its checkpoints are self-contained); with PJRT the
    // params come from a real training run instead — see `exp ckpt`.
    let mut h_rows = Vec::new();
    // (executor errors are only counted per run, not per shard, so
    // the table carries them in the note line above it)
    let mut h_table = Table::new(&[
        "shard",
        "requests",
        "param v",
        "swaps",
        "regressions",
    ]);
    let hot_swap_note;
    if exec.name() == "host" {
        let rep = hot_swap_under_load(&ds, &meta, exec.as_ref(), &scfg)?;
        println!("{}", rep.summary());
        hot_swap_note = format!(
            "A second checkpoint lands mid-run (watcher poll 5 ms): \
             {} requests completed with {} errors; final param version \
             {} after {} swap(s).\n\n",
            rep.requests,
            rep.errors,
            rep.param_version,
            rep.swaps
        );
        for sh in &rep.shards {
            h_table.row(vec![
                format!("{}", sh.id),
                format!("{}", sh.requests),
                format!("{}", sh.param_version),
                format!("{}", sh.swaps),
                format!("{}", sh.version_regressions),
            ]);
        }
        h_rows.push(rep.to_json());
    } else {
        hot_swap_note =
            "(skipped: PJRT executor active — host-model checkpoints \
             do not apply; see `exp ckpt` for the trained-parameter \
             pipeline)\n\n"
                .to_string();
    }

    let md = format!(
        "# Online serving — community-bias, shard and offered-load \
         sweeps ({name})\n\n\
         Closed loop: {} clients x {} requests, zipf {}, batch cap {}, \
         executor `{}`.\n\n\
         ## Community-bias knob (1 shard)\n\n{}\n\
         ## Shard sweep (p = {}, spill = {})\n\n{}\n\
         ## Offered-load sweep (open loop, Poisson arrivals, p = {})\n\n\
         Same trace volume issued at a fixed offered rate instead of \
         closed-loop self-pacing; `admission=none` rides the latency \
         cliff past saturation (bounded only by queue-full drop-tail), \
         `admission=reject` sheds unmeetable requests at enqueue and \
         keeps p99 bounded.\n\n{}\n\
         ## Hot swap under load (2 shards, closed loop)\n\n{}{}",
        lcfg.clients,
        lcfg.requests_per_client,
        lcfg.zipf_s,
        scfg.batch_size,
        exec.name(),
        p_table.to_markdown(),
        shard_p,
        spill.name(),
        s_table.to_markdown(),
        shard_p,
        a_table.to_markdown(),
        hot_swap_note,
        h_table.to_markdown()
    );
    let json = obj(vec![
        ("p_sweep", Json::Arr(p_rows)),
        ("shard_sweep", Json::Arr(s_rows)),
        ("load_sweep", Json::Arr(a_rows)),
        ("hot_swap", Json::Arr(h_rows)),
    ]);
    write_results("serve", &md, &json)
}

/// Stage two host-model checkpoints, start a watched serving run on
/// the first, and land the second mid-run: the report's per-shard
/// `param_version` / `swaps` counters show the zero-downtime swap.
fn hot_swap_under_load(
    ds: &crate::graph::Dataset,
    meta: &crate::runtime::artifact::ArtifactMeta,
    exec: &dyn crate::serve::InferExecutor,
    scfg: &ServeConfig,
) -> Result<crate::serve::ServeReport> {
    use crate::ckpt::{CheckpointWriter, Retention};
    use crate::config::TrainConfig;

    // two quick training stages → two checkpoints
    let stage = std::env::temp_dir().join(format!(
        "comm_rand_expserve_stage_{}",
        std::process::id()
    ));
    std::fs::remove_dir_all(&stage).ok();
    let mut w = CheckpointWriter::new(&stage, 1, Retention::All)?;
    let tcfg = TrainConfig {
        batch_size: 256,
        lr: 0.5,
        max_epochs: 2,
        seed: scfg.seed,
        ..Default::default()
    };
    crate::train::train_host(ds, &tcfg, Some(&mut w), false)?;
    let mut entries: Vec<_> = w.entries().to_vec();
    entries.sort_by_key(|e| e.epoch);
    if entries.len() != 2 {
        anyhow::bail!("expected 2 staged checkpoints, got {}", entries.len());
    }

    // the watch dir starts with only the first checkpoint
    let watch = std::env::temp_dir().join(format!(
        "comm_rand_expserve_watch_{}",
        std::process::id()
    ));
    std::fs::remove_dir_all(&watch).ok();
    std::fs::create_dir_all(&watch)?;
    let first = crate::ckpt::Checkpoint::load(&entries[0].path)?;
    first.write_atomic(&watch.join("ckpt-e00000.bin"))?;
    let second = crate::ckpt::Checkpoint::load(&entries[1].path)?;

    let cfg = ServeConfig {
        shards: 2,
        workers: 2,
        // stretch the run so the mid-run swap lands well before the
        // trace drains
        max_delay_us: 3_000,
        ckpt: Some(watch.clone()),
        ckpt_watch_ms: 5,
        ..scfg.clone()
    };
    let lcfg = LoadConfig {
        clients: 4,
        requests_per_client: 60,
        zipf_s: 1.1,
        arrival: Arrival::Closed,
        seed: scfg.seed ^ 0x5A5A,
    };
    let rep = std::thread::scope(|scope| {
        let watch_ref = &watch;
        let second_ref = &second;
        let writer = scope.spawn(move || {
            std::thread::sleep(std::time::Duration::from_millis(60));
            second_ref
                .write_atomic(&watch_ref.join("ckpt-e00001.bin"))
                .expect("staging the swap checkpoint");
        });
        let rep = engine::run(ds, meta, exec, &cfg, &lcfg);
        let _ = writer.join();
        rep
    })?;
    std::fs::remove_dir_all(&stage).ok();
    std::fs::remove_dir_all(&watch).ok();
    Ok(rep)
}
