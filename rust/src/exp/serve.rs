//! Serving-knob sweep: the online-inference analogue of the paper's
//! training figures. Replays the same Zipf closed-loop trace against
//! the serving engine for community-bias `p ∈ {0, 0.5, 1}` and tabulates
//! throughput, tail latency and feature-cache hit rate — the quantity
//! the knob exists to move.
//!
//! Unlike the training experiments this needs no PJRT session: it uses
//! the compiled infer artifact when available and the no-op executor
//! otherwise, so `comm-rand exp serve` runs in artifact-less
//! environments too.

use anyhow::Result;

use crate::cli::Args;
use crate::config::preset;
use crate::serve::{engine, LoadConfig, ServeConfig};
use crate::util::json::Json;

use super::common::{f2, pct, quick, write_results, Table};

pub fn run(args: &Args) -> Result<()> {
    let name = args.pos.get(1).map(String::as_str).unwrap_or("tiny");
    let p = preset(name)
        .ok_or_else(|| anyhow::anyhow!("unknown preset {name}"))?;
    let ds = crate::train::dataset::load_or_build(&p, true)?;

    let mut scfg = ServeConfig::for_dataset(&ds);
    scfg.batch_size = args.get_usize("batch", 32)?;
    scfg.seed = args.get_u64("seed", 0)?;
    let lcfg = LoadConfig {
        clients: args.get_usize("clients", 8)?,
        requests_per_client: args
            .get_usize("requests", if quick() { 40 } else { 200 })?,
        zipf_s: args.get_f64("zipf", 1.1)?,
        seed: scfg.seed ^ 0x10AD,
    };
    let (exec, meta) = engine::build_executor(&p, &ds, &scfg);

    let mut table = Table::new(&[
        "p",
        "req/s",
        "p50 ms",
        "p95 ms",
        "p99 ms",
        "cache hit",
        "req/batch",
    ]);
    let mut rows = Vec::new();
    for bias in [0.0, 0.5, 1.0] {
        let cfg = ServeConfig { community_bias: bias, ..scfg.clone() };
        let rep = engine::run(&ds, &meta, exec.as_ref(), &cfg, &lcfg)?;
        println!("{}", rep.summary());
        table.row(vec![
            f2(bias),
            format!("{:.0}", rep.throughput_rps),
            f2(rep.lat_p50_ms),
            f2(rep.lat_p95_ms),
            f2(rep.lat_p99_ms),
            pct(rep.cache_hit_rate),
            f2(rep.mean_batch_size),
        ]);
        rows.push(rep.to_json());
    }

    let md = format!(
        "# Online serving — community-bias knob sweep ({name})\n\n\
         Closed loop: {} clients x {} requests, zipf {}, batch cap {}, \
         executor `{}`.\n\n{}",
        lcfg.clients,
        lcfg.requests_per_client,
        lcfg.zipf_s,
        scfg.batch_size,
        exec.name(),
        table.to_markdown()
    );
    write_results("serve", &md, &Json::Arr(rows))
}
