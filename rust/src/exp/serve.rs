//! Serving-knob sweeps: the online-inference analogue of the paper's
//! training figures. Replays the same Zipf trace against the serving
//! engine along three axes:
//!
//! * community-bias `p ∈ {0, 0.5, 1}` on one shard — the knob's effect
//!   on throughput, tail latency and feature-cache hit rate (closed
//!   loop);
//! * shard count `∈ {1, 2, 4}` at fixed `p` — community-affinity
//!   scaling: each shard's cache only sees its own communities, so the
//!   aggregate hit rate should hold (or improve) as the per-shard
//!   cache slice shrinks (closed loop);
//! * offered load × admission policy — open-loop Poisson arrivals
//!   swept past saturation, `admission ∈ {none, reject}`: with `none`
//!   the p99 latency diverges with the backlog (the latency cliff, at
//!   best clipped by queue-full drop-tail); with `reject` unmeetable
//!   requests are shed at enqueue, so p99 stays bounded and the
//!   shed-rate column shows the price.
//!
//! Unlike the training experiments this needs no PJRT session: it uses
//! the compiled infer artifact when available and the no-op executor
//! otherwise, so `comm-rand exp serve` runs in artifact-less
//! environments too.

use anyhow::{Context, Result};

use crate::cli::Args;
use crate::config::preset;
use crate::serve::{
    engine, AdmissionPolicy, Arrival, LoadConfig, ServeConfig, SpillPolicy,
};
use crate::util::json::{obj, Json};

use super::common::{f2, pct, quick, write_results, Table};

pub fn run(args: &Args) -> Result<()> {
    let name = args.pos.get(1).map(String::as_str).unwrap_or("tiny");
    let p = preset(name)
        .ok_or_else(|| anyhow::anyhow!("unknown preset {name}"))?;
    let ds = crate::train::dataset::load_or_build(&p, true)?;

    let mut scfg = ServeConfig::for_dataset(&ds);
    scfg.batch_size = args.get_usize("batch", 32)?;
    scfg.seed = args.get_u64("seed", 0)?;
    let spill = SpillPolicy::parse(args.get("spill").unwrap_or("strict"))?;
    let lcfg = LoadConfig {
        clients: args.get_usize("clients", 8)?,
        requests_per_client: args
            .get_usize("requests", if quick() { 40 } else { 200 })?,
        zipf_s: args.get_f64("zipf", 1.1)?,
        arrival: Arrival::Closed,
        seed: scfg.seed ^ 0x10AD,
    };
    let (exec, meta) = engine::build_executor(&p, &ds, &scfg);

    // axis 1: community-bias knob on a single shard
    let mut p_table = Table::new(&[
        "p",
        "req/s",
        "p50 ms",
        "p95 ms",
        "p99 ms",
        "cache hit",
        "req/batch",
    ]);
    let shard_p = args.get_f64("shard_p", 1.0)?;
    if !(0.0..=1.0).contains(&shard_p) {
        anyhow::bail!("shard_p must be in [0, 1], got {shard_p}");
    }
    let mut p_rows = Vec::new();
    // the p-sweep row matching (shard_p, 1 shard, default spill) doubles
    // as the shard sweep's baseline, so that config isn't re-run below
    let mut one_shard_baseline = None;
    for bias in [0.0, 0.5, 1.0] {
        let cfg = ServeConfig { community_bias: bias, ..scfg.clone() };
        let rep = engine::run(&ds, &meta, exec.as_ref(), &cfg, &lcfg)?;
        println!("{}", rep.summary());
        p_table.row(vec![
            f2(bias),
            format!("{:.0}", rep.throughput_rps),
            f2(rep.lat_p50_ms),
            f2(rep.lat_p95_ms),
            f2(rep.lat_p99_ms),
            pct(rep.cache_hit_rate),
            f2(rep.mean_batch_size),
        ]);
        p_rows.push(rep.to_json());
        if bias == shard_p && scfg.shards == 1 && spill == scfg.spill {
            one_shard_baseline = Some(rep);
        }
    }

    // axis 2: shard count at fixed p (community affinity across
    // logical devices, `spill=` selects the cross-shard policy)
    let mut s_table = Table::new(&[
        "shards",
        "spill",
        "req/s",
        "p50 ms",
        "p99 ms",
        "cache hit",
        "foreign",
        "depth max",
    ]);
    let mut s_rows = Vec::new();
    for n_shards in [1usize, 2, 4] {
        let rep = match (n_shards, one_shard_baseline.take()) {
            (1, Some(baseline)) => baseline, // identical config: reuse
            _ => {
                let cfg = ServeConfig {
                    community_bias: shard_p,
                    shards: n_shards,
                    spill,
                    ..scfg.clone()
                };
                let rep = engine::run(&ds, &meta, exec.as_ref(), &cfg, &lcfg)?;
                println!("{}", rep.summary());
                rep
            }
        };
        let depth_max =
            rep.shards.iter().map(|sh| sh.queue_depth_max).max().unwrap_or(0);
        s_table.row(vec![
            format!("{n_shards}"),
            spill.name().to_string(),
            format!("{:.0}", rep.throughput_rps),
            f2(rep.lat_p50_ms),
            f2(rep.lat_p99_ms),
            pct(rep.cache_hit_rate),
            format!("{}", rep.foreign_requests()),
            format!("{depth_max}"),
        ]);
        s_rows.push(rep.to_json());
    }

    // axis 3: offered load x admission policy (open-loop Poisson).
    // The sweep deliberately crosses the saturation rate: closed-loop
    // throughput above tells us roughly where it is, and the top rates
    // sit well past it, so the `none` rows show the latency cliff and
    // the `reject` rows show it clipped (nonzero shed-rate instead).
    let rates: Vec<f64> = match args.get("rates") {
        Some(spec) => spec
            .split(',')
            .map(|v| v.trim().parse::<f64>().context("bad rates= value"))
            .collect::<Result<Vec<f64>>>()?,
        None if quick() => vec![2_000.0, 16_000.0],
        None => vec![2_000.0, 8_000.0, 32_000.0, 128_000.0],
    };
    // same validity rule Arrival::parse enforces on the CLI path — a
    // zero/negative/NaN rate would make the open-loop clients sleep
    // (near) forever instead of erroring
    for &r in &rates {
        if !(r.is_finite() && r > 0.0) {
            anyhow::bail!("rates= values must be positive numbers, got {r}");
        }
    }
    let mut a_table = Table::new(&[
        "rate rps",
        "admission",
        "done",
        "done rps",
        "p50 ms",
        "p99 ms",
        "shed rate",
        "degraded",
    ]);
    let mut a_rows = Vec::new();
    for &rate in &rates {
        for adm in [AdmissionPolicy::None, AdmissionPolicy::Reject] {
            let cfg = ServeConfig {
                community_bias: shard_p,
                admission: adm,
                ..scfg.clone()
            };
            let l = LoadConfig {
                arrival: Arrival::Poisson { rate_rps: rate },
                ..lcfg.clone()
            };
            let rep = engine::run(&ds, &meta, exec.as_ref(), &cfg, &l)?;
            println!("{}", rep.summary());
            a_table.row(vec![
                format!("{rate:.0}"),
                adm.name().to_string(),
                format!("{}", rep.requests),
                format!("{:.0}", rep.throughput_rps),
                f2(rep.lat_p50_ms),
                f2(rep.lat_p99_ms),
                pct(rep.shed_rate),
                format!("{}", rep.degraded),
            ]);
            a_rows.push(rep.to_json());
        }
    }

    let md = format!(
        "# Online serving — community-bias, shard and offered-load \
         sweeps ({name})\n\n\
         Closed loop: {} clients x {} requests, zipf {}, batch cap {}, \
         executor `{}`.\n\n\
         ## Community-bias knob (1 shard)\n\n{}\n\
         ## Shard sweep (p = {}, spill = {})\n\n{}\n\
         ## Offered-load sweep (open loop, Poisson arrivals, p = {})\n\n\
         Same trace volume issued at a fixed offered rate instead of \
         closed-loop self-pacing; `admission=none` rides the latency \
         cliff past saturation (bounded only by queue-full drop-tail), \
         `admission=reject` sheds unmeetable requests at enqueue and \
         keeps p99 bounded.\n\n{}",
        lcfg.clients,
        lcfg.requests_per_client,
        lcfg.zipf_s,
        scfg.batch_size,
        exec.name(),
        p_table.to_markdown(),
        shard_p,
        spill.name(),
        s_table.to_markdown(),
        shard_p,
        a_table.to_markdown()
    );
    let json = obj(vec![
        ("p_sweep", Json::Arr(p_rows)),
        ("shard_sweep", Json::Arr(s_rows)),
        ("load_sweep", Json::Arr(a_rows)),
    ]);
    write_results("serve", &md, &json)
}
