//! Serving-knob sweeps: the online-inference analogue of the paper's
//! training figures. Replays the same Zipf closed-loop trace against
//! the serving engine along two axes:
//!
//! * community-bias `p ∈ {0, 0.5, 1}` on one shard — the knob's effect
//!   on throughput, tail latency and feature-cache hit rate;
//! * shard count `∈ {1, 2, 4}` at fixed `p` — community-affinity
//!   scaling: each shard's cache only sees its own communities, so the
//!   aggregate hit rate should hold (or improve) as the per-shard
//!   cache slice shrinks.
//!
//! Unlike the training experiments this needs no PJRT session: it uses
//! the compiled infer artifact when available and the no-op executor
//! otherwise, so `comm-rand exp serve` runs in artifact-less
//! environments too.

use anyhow::Result;

use crate::cli::Args;
use crate::config::preset;
use crate::serve::{engine, LoadConfig, ServeConfig, SpillPolicy};
use crate::util::json::{obj, Json};

use super::common::{f2, pct, quick, write_results, Table};

pub fn run(args: &Args) -> Result<()> {
    let name = args.pos.get(1).map(String::as_str).unwrap_or("tiny");
    let p = preset(name)
        .ok_or_else(|| anyhow::anyhow!("unknown preset {name}"))?;
    let ds = crate::train::dataset::load_or_build(&p, true)?;

    let mut scfg = ServeConfig::for_dataset(&ds);
    scfg.batch_size = args.get_usize("batch", 32)?;
    scfg.seed = args.get_u64("seed", 0)?;
    let spill = SpillPolicy::parse(args.get("spill").unwrap_or("strict"))?;
    let lcfg = LoadConfig {
        clients: args.get_usize("clients", 8)?,
        requests_per_client: args
            .get_usize("requests", if quick() { 40 } else { 200 })?,
        zipf_s: args.get_f64("zipf", 1.1)?,
        seed: scfg.seed ^ 0x10AD,
    };
    let (exec, meta) = engine::build_executor(&p, &ds, &scfg);

    // axis 1: community-bias knob on a single shard
    let mut p_table = Table::new(&[
        "p",
        "req/s",
        "p50 ms",
        "p95 ms",
        "p99 ms",
        "cache hit",
        "req/batch",
    ]);
    let shard_p = args.get_f64("shard_p", 1.0)?;
    if !(0.0..=1.0).contains(&shard_p) {
        anyhow::bail!("shard_p must be in [0, 1], got {shard_p}");
    }
    let mut p_rows = Vec::new();
    // the p-sweep row matching (shard_p, 1 shard, default spill) doubles
    // as the shard sweep's baseline, so that config isn't re-run below
    let mut one_shard_baseline = None;
    for bias in [0.0, 0.5, 1.0] {
        let cfg = ServeConfig { community_bias: bias, ..scfg.clone() };
        let rep = engine::run(&ds, &meta, exec.as_ref(), &cfg, &lcfg)?;
        println!("{}", rep.summary());
        p_table.row(vec![
            f2(bias),
            format!("{:.0}", rep.throughput_rps),
            f2(rep.lat_p50_ms),
            f2(rep.lat_p95_ms),
            f2(rep.lat_p99_ms),
            pct(rep.cache_hit_rate),
            f2(rep.mean_batch_size),
        ]);
        p_rows.push(rep.to_json());
        if bias == shard_p && scfg.shards == 1 && spill == scfg.spill {
            one_shard_baseline = Some(rep);
        }
    }

    // axis 2: shard count at fixed p (community affinity across
    // logical devices, `spill=` selects the cross-shard policy)
    let mut s_table = Table::new(&[
        "shards",
        "spill",
        "req/s",
        "p50 ms",
        "p99 ms",
        "cache hit",
        "foreign",
        "depth max",
    ]);
    let mut s_rows = Vec::new();
    for n_shards in [1usize, 2, 4] {
        let rep = match (n_shards, one_shard_baseline.take()) {
            (1, Some(baseline)) => baseline, // identical config: reuse
            _ => {
                let cfg = ServeConfig {
                    community_bias: shard_p,
                    shards: n_shards,
                    spill,
                    ..scfg.clone()
                };
                let rep = engine::run(&ds, &meta, exec.as_ref(), &cfg, &lcfg)?;
                println!("{}", rep.summary());
                rep
            }
        };
        let depth_max =
            rep.shards.iter().map(|sh| sh.queue_depth_max).max().unwrap_or(0);
        s_table.row(vec![
            format!("{n_shards}"),
            spill.name().to_string(),
            format!("{:.0}", rep.throughput_rps),
            f2(rep.lat_p50_ms),
            f2(rep.lat_p99_ms),
            pct(rep.cache_hit_rate),
            format!("{}", rep.foreign_requests()),
            format!("{depth_max}"),
        ]);
        s_rows.push(rep.to_json());
    }

    let md = format!(
        "# Online serving — community-bias knob and shard sweeps ({name})\n\n\
         Closed loop: {} clients x {} requests, zipf {}, batch cap {}, \
         executor `{}`.\n\n\
         ## Community-bias knob (1 shard)\n\n{}\n\
         ## Shard sweep (p = {}, spill = {})\n\n{}",
        lcfg.clients,
        lcfg.requests_per_client,
        lcfg.zipf_s,
        scfg.batch_size,
        exec.name(),
        p_table.to_markdown(),
        shard_p,
        spill.name(),
        s_table.to_markdown()
    );
    let json = obj(vec![
        ("p_sweep", Json::Arr(p_rows)),
        ("shard_sweep", Json::Arr(s_rows)),
    ]);
    write_results("serve", &md, &json)
}
