//! Figure 9 — software-managed feature cache for mixed CPU-GPU (UVA)
//! training on the papers100M stand-in: per-epoch speedups with and
//! without a GPU-resident feature cache, plus the per-policy cache
//! miss rates the paper quotes (35.46% baseline -> 6.21% for
//! COMM-RAND-MIX-0%).

use anyhow::Result;

use crate::config::{BatchPolicy, TrainConfig};
use crate::sampler::RootPolicy;
use crate::train::Method;
use crate::util::json::{num, obj, s, Json};

use super::common::*;

pub fn run(ctx: &mut Ctx) -> Result<()> {
    let (p, ds) = ctx.dataset("papers_sim")?;
    // paper: a 4M-row cache on papers100M covers most of the training
    // working set (1.2M train roots' sampled frontiers). The matching
    // regime here is ~25% of nodes: big enough that community-biased
    // epochs become cache-resident while the uniform baseline still
    // thrashes.
    let cache_rows = ds.n() / 4;
    let cfg = TrainConfig { max_epochs: if quick() { 3 } else { 6 }, ..Default::default() };

    let policies: Vec<(String, BatchPolicy)> = vec![
        ("baseline".into(), BatchPolicy::baseline()),
        (
            "MIX-50%+p1.0".into(),
            BatchPolicy { roots: RootPolicy::CommRandMix { pct: 0.50 }, p_intra: 1.0 },
        ),
        (
            "MIX-25%+p1.0".into(),
            BatchPolicy { roots: RootPolicy::CommRandMix { pct: 0.25 }, p_intra: 1.0 },
        ),
        (
            "MIX-12.5%+p1.0".into(),
            BatchPolicy { roots: RootPolicy::CommRandMix { pct: 0.125 }, p_intra: 1.0 },
        ),
        (
            "MIX-0%+p1.0".into(),
            BatchPolicy { roots: RootPolicy::CommRandMix { pct: 0.0 }, p_intra: 1.0 },
        ),
    ];

    let mut md = String::from(
        "# Figure 9 — per-epoch speedup with a software feature cache \
         (papers_sim, UVA)\n\n",
    );
    let mut t = Table::new(&[
        "policy", "speedup (no SW cache)", "speedup (SW cache)",
        "SW miss rate",
    ]);
    let mut jrows = Vec::new();
    let mut base_no = 0.0;
    let mut base_sw = 0.0;
    for (label, pol) in &policies {
        let r_no = ctx.run(
            &p, &ds, &Method::CommRand(pol.clone()), &cfg, |_| {})?;
        let r_sw = ctx.run(&p, &ds, &Method::CommRand(pol.clone()), &cfg, |o| {
            o.sw_cache_rows = Some(cache_rows);
        })?;
        let t_no = r_no.mean_epoch_modeled_s();
        let t_sw = r_sw.mean_epoch_modeled_s();
        let miss = r_sw
            .epochs
            .last()
            .map(|e| e.sw_miss_rate)
            .unwrap_or(f64::NAN);
        if label == "baseline" {
            base_no = t_no;
            base_sw = t_sw;
        }
        t.row(vec![
            label.clone(),
            format!("{:.2}x", base_no / t_no),
            format!("{:.2}x", base_sw / t_sw),
            pct(miss),
        ]);
        jrows.push(obj(vec![
            ("policy", s(label)),
            ("epoch_s_nocache", num(t_no)),
            ("epoch_s_swcache", num(t_sw)),
            ("sw_miss_rate", num(miss)),
        ]));
        println!("[fig9] {label} done (miss {miss:.3})");
    }
    md.push_str(&t.to_markdown());
    md.push_str(&format!(
        "\nSW cache capacity: {cache_rows} feature rows \
         ({:.1}% of nodes). Community-biased policies reuse the cache \
         and cut UVA transfers, mirroring the paper's 35% -> 6% miss \
         rate trend.\n",
        100.0 * cache_rows as f64 / ds.n() as f64
    ));
    write_results("fig9", &md, &Json::Arr(jrows))
}
