//! Knob auto-tuning (the paper's §6.1.3 future-work suggestion: "cast
//! the problem of finding the right bias level as a learning problem").
//!
//! Successive halving over the (root policy x p) grid: every surviving
//! configuration gets a doubling epoch budget; half are eliminated per
//! rung by a cost-adjusted score
//!
//! ```text
//! score = val_acc - lambda * ln(epoch_time / baseline_time)
//! ```
//!
//! so the tuner trades accuracy against per-epoch cost exactly the way
//! the paper's manual exploration does. Reports the chosen knobs and
//! compares against the paper's recommended MIX-12.5% + p=1.0.

use anyhow::Result;

use crate::config::{BatchPolicy, TrainConfig};
use crate::train::Method;
use crate::util::json::{num, obj, s, Json};

use super::common::*;

pub fn run(ctx: &mut Ctx) -> Result<()> {
    let ds_name = if quick() { "reddit_sim" } else { "reddit_sim" };
    let (p, ds) = ctx.dataset(ds_name)?;
    let lambda = 0.05;

    // rung 0 candidates: the full fig5 grid
    let mut survivors: Vec<BatchPolicy> = Vec::new();
    for roots in root_grid() {
        for p_intra in p_grid() {
            survivors.push(BatchPolicy { roots, p_intra });
        }
    }

    // baseline epoch time for the cost term
    let probe_cfg = TrainConfig { max_epochs: 1, ..Default::default() };
    let base = ctx.run(
        &p, &ds, &Method::CommRand(BatchPolicy::baseline()), &probe_cfg, |_| {})?;
    let base_epoch = base.mean_epoch_modeled_s();

    let mut md = String::from(
        "# Auto-tuning the COMM-RAND knobs (successive halving)\n\n",
    );
    let mut budget = 1usize;
    let mut rung = 0;
    let mut jrungs = Vec::new();
    while survivors.len() > 1 {
        let mut scored: Vec<(f64, BatchPolicy, f64, f64)> = Vec::new();
        for pol in &survivors {
            let cfg = TrainConfig {
                max_epochs: budget,
                patience: usize::MAX,
                ..Default::default()
            };
            let r = ctx.run(&p, &ds, &Method::CommRand(pol.clone()), &cfg, |_| {})?;
            let t_epoch = r.mean_epoch_modeled_s();
            let score =
                r.best_val_acc - lambda * (t_epoch / base_epoch).ln();
            scored.push((score, pol.clone(), r.best_val_acc, t_epoch));
        }
        scored.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap());
        let keep = (scored.len() + 1) / 2;
        println!(
            "[autotune] rung {rung} (budget {budget} ep): best {} \
             (score {:.4}), keeping {keep}/{}",
            scored[0].1.label(),
            scored[0].0,
            scored.len()
        );
        jrungs.push(obj(vec![
            ("rung", num(rung as f64)),
            ("budget_epochs", num(budget as f64)),
            ("best", s(&scored[0].1.label())),
            ("best_score", num(scored[0].0)),
            ("candidates", num(scored.len() as f64)),
        ]));
        md.push_str(&format!(
            "* rung {rung} (budget {budget} epochs): best `{}` \
             score {:.4}, acc {:.4}, epoch {:.4}ms — kept {keep}/{}\n",
            scored[0].1.label(),
            scored[0].0,
            scored[0].2,
            scored[0].3 * 1e3,
            scored.len()
        ));
        survivors = scored.into_iter().take(keep).map(|x| x.1).collect();
        budget *= 2;
        rung += 1;
        if rung > 6 {
            break;
        }
    }
    let winner = survivors[0].clone();

    // final comparison: winner vs paper-recommended knobs, full budget
    let cfg = TrainConfig { max_epochs: max_epochs(), ..Default::default() };
    let rw = ctx.run(&p, &ds, &Method::CommRand(winner.clone()), &cfg, |_| {})?;
    let rp = ctx.run(&p, &ds, &Method::CommRand(best_policy()), &cfg, |_| {})?;
    md.push_str(&format!(
        "\nwinner: **{}** — acc {:.4}, total modeled {:.2}ms\n\
         paper's pick (MIX-12.5%+p1.0): acc {:.4}, total modeled {:.2}ms\n",
        winner.label(),
        rw.best_val_acc,
        rw.modeled_to_convergence() * 1e3,
        rp.best_val_acc,
        rp.modeled_to_convergence() * 1e3,
    ));
    let json = obj(vec![
        ("rungs", Json::Arr(jrungs)),
        ("winner", s(&winner.label())),
        ("winner_acc", num(rw.best_val_acc)),
        ("winner_total_modeled_s", num(rw.modeled_to_convergence())),
        ("paper_pick_acc", num(rp.best_val_acc)),
        ("paper_pick_total_modeled_s", num(rp.modeled_to_convergence())),
    ]);
    write_results("autotune", &md, &json)
}
