//! Figure 10 — L2-capacity sensitivity (the paper's MIG study): the
//! modeled per-epoch speedup of COMM-RAND configurations grows as the
//! L2 shrinks (40MB -> 20MB -> 10MB equivalents), because the baseline
//! thrashes harder while community-biased batches keep fitting.

use anyhow::Result;

use crate::config::{BatchPolicy, TrainConfig};
use crate::sampler::RootPolicy;
use crate::train::Method;
use crate::util::json::{num, obj, s, Json};

use super::common::*;

pub fn run(ctx: &mut Ctx) -> Result<()> {
    let (p, ds) = ctx.dataset("reddit_sim")?;
    let cfg = TrainConfig { max_epochs: 2, ..Default::default() };
    let scales = [("40MB-eq", 1.0), ("20MB-eq", 0.5), ("10MB-eq", 0.25)];
    let policies: Vec<(String, BatchPolicy)> = vec![
        ("baseline".into(), BatchPolicy::baseline()),
        (
            "MIX-50%+p1.0".into(),
            BatchPolicy { roots: RootPolicy::CommRandMix { pct: 0.50 }, p_intra: 1.0 },
        ),
        (
            "MIX-12.5%+p1.0".into(),
            BatchPolicy { roots: RootPolicy::CommRandMix { pct: 0.125 }, p_intra: 1.0 },
        ),
        (
            "MIX-0%+p1.0".into(),
            BatchPolicy { roots: RootPolicy::CommRandMix { pct: 0.0 }, p_intra: 1.0 },
        ),
        (
            "NORAND+p1.0".into(),
            BatchPolicy { roots: RootPolicy::NoRand, p_intra: 1.0 },
        ),
    ];

    let mut md = String::from(
        "# Figure 10 — per-epoch speedup vs L2 capacity (reddit_sim)\n\n",
    );
    let mut t = Table::new(&["policy", "40MB-eq", "20MB-eq", "10MB-eq"]);
    let mut jrows = Vec::new();
    let mut base = [0.0f64; 3];
    for (label, pol) in &policies {
        let mut row = vec![label.clone()];
        let mut jcells = vec![("policy", s(label))];
        for (i, (sname, scale)) in scales.iter().enumerate() {
            let r = ctx.run(&p, &ds, &Method::CommRand(pol.clone()), &cfg, |o| {
                o.l2_scale = *scale;
            })?;
            let tt = r.mean_epoch_modeled_s();
            if label == "baseline" {
                base[i] = tt;
            }
            row.push(format!("{:.2}x", base[i] / tt));
            jcells.push((
                match i {
                    0 => "speedup_40mb",
                    1 => "speedup_20mb",
                    _ => "speedup_10mb",
                },
                num(base[i] / tt),
            ));
            let _ = sname;
        }
        t.row(row);
        jrows.push(obj(jcells));
        println!("[fig10] {label} done");
    }
    md.push_str(&t.to_markdown());
    md.push_str(
        "\nSpeedups are normalized to the baseline *within each L2 \
         configuration*; smaller caches widen COMM-RAND's advantage.\n",
    );
    write_results("fig10", &md, &Json::Arr(jrows))
}
