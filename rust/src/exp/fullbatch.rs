//! §2's mini-batch vs full-batch comparison: full-graph GCN gradient
//! descent (one update per epoch) vs mini-batched training on the same
//! GCN architecture. The paper reports mini-batching converging in
//! ~10x fewer epochs and ~2.7x faster overall despite slower epochs.

use anyhow::Result;

use crate::config::{BatchPolicy, TrainConfig};
use crate::runtime::FullBatchState;
use crate::train::Method;
use crate::util::json::{num, obj, s, Json};
use crate::util::timer::Timer;

use super::common::*;

pub fn run(ctx: &mut Ctx) -> Result<()> {
    let (p, ds) = ctx.dataset("reddit_sim")?;
    let max_epochs = if quick() { 30 } else { 120 };
    let target_acc = 0.60; // common convergence bar for both schemes

    // --- full batch ---
    let fb_meta = ctx.session.meta("reddit_sim_fb.train")?;
    let mut fb = FullBatchState::new(&ctx.session.rt, &fb_meta, &ds, 1e-2, 0)?;
    let n_train = ds.train_nodes().len();
    let n_val = ds.val_nodes().len();
    let t = Timer::start();
    let mut fb_epochs = max_epochs * 4;
    let mut fb_acc = 0.0;
    for e in 0..max_epochs * 4 {
        let out = fb.step(n_train, n_val)?;
        fb_acc = out.acc_val as f64;
        if fb_acc >= target_acc {
            fb_epochs = e + 1;
            break;
        }
    }
    let fb_wall = t.elapsed_s();
    let fb_per_epoch = fb_wall / fb_epochs.max(1) as f64;
    println!(
        "[fullbatch] full-batch: {fb_epochs} epochs, acc {fb_acc:.4}, \
         {fb_per_epoch:.3}s/epoch"
    );

    // --- mini batch (same GCN architecture) ---
    let mut p_gcn = p.clone();
    p_gcn.artifact = "reddit_sim_gcn";
    let cfg = TrainConfig {
        max_epochs,
        patience: usize::MAX,
        ..Default::default()
    };
    let r = ctx.run(
        &p_gcn, &ds, &Method::CommRand(BatchPolicy::baseline()), &cfg, |_| {})?;
    let mb_epochs = r
        .epochs
        .iter()
        .position(|e| e.val_acc >= target_acc)
        .map(|i| i + 1)
        .unwrap_or(r.epochs.len());
    let mb_per_epoch = r.mean_epoch_wall_s();
    let mb_wall: f64 = r.epochs.iter().take(mb_epochs).map(|e| e.wall_s).sum();
    println!(
        "[fullbatch] mini-batch: {mb_epochs} epochs to {target_acc}, \
         {mb_per_epoch:.3}s/epoch"
    );

    let mut md = String::from(
        "# §2 — mini-batch vs full-batch GCN training (reddit_sim)\n\n",
    );
    let mut t = Table::new(&[
        "scheme", "epochs to target", "per-epoch wall (s)",
        "total wall (s)", "val acc reached",
    ]);
    t.row(vec![
        "full-batch".into(),
        fb_epochs.to_string(),
        format!("{fb_per_epoch:.3}"),
        format!("{fb_wall:.1}"),
        f4(fb_acc),
    ]);
    t.row(vec![
        "mini-batch".into(),
        mb_epochs.to_string(),
        format!("{mb_per_epoch:.3}"),
        format!("{mb_wall:.1}"),
        f4(r.best_val_acc),
    ]);
    md.push_str(&t.to_markdown());
    md.push_str(&format!(
        "\nmini-batch needs {:.1}x fewer epochs (paper: 10.2x avg) and is \
         {:.2}x faster to the {target_acc} val-acc bar (paper: 2.7x).\n",
        fb_epochs as f64 / mb_epochs.max(1) as f64,
        fb_wall / mb_wall.max(1e-9),
    ));
    let json = Json::Arr(vec![
        obj(vec![
            ("scheme", s("fullbatch")),
            ("epochs", num(fb_epochs as f64)),
            ("per_epoch_s", num(fb_per_epoch)),
            ("total_s", num(fb_wall)),
            ("acc", num(fb_acc)),
        ]),
        obj(vec![
            ("scheme", s("minibatch")),
            ("epochs", num(mb_epochs as f64)),
            ("per_epoch_s", num(mb_per_epoch)),
            ("total_s", num(mb_wall)),
            ("acc", num(r.best_val_acc)),
        ]),
    ]);
    write_results("fullbatch", &md, &json)
}
