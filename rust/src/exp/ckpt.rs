//! Train → checkpoint → serve: the accuracy-vs-latency sweep that
//! closes the loop the ROADMAP calls "trained-parameter serving".
//!
//! Pipeline: the host trainer runs for a few epochs writing a
//! checkpoint *per epoch* (retention = keep-all, so the sweep can
//! serve every training stage), then `serve bench` replays the same
//! Zipf trace once with seed parameters and once per checkpoint. The
//! table shows top-1 serving accuracy climbing with training epoch
//! while latency stays flat — accuracy is a property of the
//! parameters, latency of the serving stack.
//!
//! This experiment is also the end-to-end smoke gate CI runs: it
//! writes `results/e2e_accuracy.json` and **fails** unless the final
//! trained checkpoint serves with accuracy meaningfully above the
//! seed-parameter baseline. No PJRT session or AOT artifacts are
//! needed — the host reference executor produces real logits anywhere.

use anyhow::{bail, Result};

use crate::ckpt::{CheckpointWriter, Retention};
use crate::cli::Args;
use crate::config::{preset, TrainConfig};
use crate::serve::{engine, Arrival, HostExecutor, LoadConfig, ServeConfig};
use crate::train::train_host;
use crate::util::json::{arr, num, obj, s, Json};

use super::common::{f2, f4, pct, quick, results_dir, write_results, Table};

pub fn run(args: &Args) -> Result<()> {
    let name = args.pos.get(1).map(String::as_str).unwrap_or("tiny");
    let p = preset(name)
        .ok_or_else(|| anyhow::anyhow!("unknown preset {name}"))?;
    let ds = crate::train::dataset::load_or_build(&p, true)?;
    let seed = args.get_u64("seed", 0)?;
    let epochs = args.get_usize("epochs", if quick() { 4 } else { 8 })?;

    // ---- train, checkpointing every epoch ----
    let dir = results_dir().join(format!("ckpts-{name}"));
    std::fs::remove_dir_all(&dir).ok();
    let mut writer = CheckpointWriter::new(&dir, 1, Retention::All)?;
    let tcfg = TrainConfig {
        batch_size: 256,
        lr: 0.5,
        max_epochs: epochs,
        seed,
        ..Default::default()
    };
    let (_, treport) = train_host(&ds, &tcfg, Some(&mut writer), false)?;
    println!("{}", treport.summary());

    // ---- serve each checkpoint against the same trace ----
    let mut scfg = ServeConfig::for_dataset(&ds);
    scfg.batch_size = 32;
    scfg.fanouts = vec![5, 5];
    scfg.seed = seed;
    let lcfg = LoadConfig {
        clients: 4,
        requests_per_client: args
            .get_usize("requests", if quick() { 40 } else { 120 })?,
        zipf_s: args.get_f64("zipf", 1.1)?,
        arrival: Arrival::Closed,
        seed: seed ^ 0x10AD,
    };
    let exec = HostExecutor::new(&ds, scfg.seed)?;
    let meta =
        engine::synthetic_infer_meta(&ds, scfg.batch_size, &scfg.fanouts);

    let mut table = Table::new(&[
        "params",
        "train val acc",
        "serve acc",
        "req/s",
        "p50 ms",
        "p99 ms",
        "param v",
    ]);
    let mut rows = Vec::new();
    let mut serve_one = |label: String,
                         val_acc: f64,
                         cfg: &ServeConfig|
     -> Result<(f64, Json)> {
        let rep = engine::run(&ds, &meta, &exec, cfg, &lcfg)?;
        println!("{}", rep.summary());
        table.row(vec![
            label.clone(),
            f4(val_acc),
            pct(rep.accuracy),
            format!("{:.0}", rep.throughput_rps),
            f2(rep.lat_p50_ms),
            f2(rep.lat_p99_ms),
            format!("{}", rep.param_version),
        ]);
        let j = obj(vec![
            ("params", s(&label)),
            ("train_val_acc", num(val_acc)),
            ("serve_accuracy", num(rep.accuracy)),
            ("evaluated", num(rep.evaluated as f64)),
            ("throughput_rps", num(rep.throughput_rps)),
            ("lat_p50_ms", num(rep.lat_p50_ms)),
            ("lat_p99_ms", num(rep.lat_p99_ms)),
            ("param_version", num(rep.param_version as f64)),
            ("errors", num(rep.errors as f64)),
        ]);
        Ok((rep.accuracy, j))
    };

    // seed baseline first: the executor has no checkpoint installed yet
    let (seed_acc, j) = serve_one("seed".into(), 0.0, &scfg)?;
    rows.push(j);

    let mut entries: Vec<_> = writer.entries().to_vec();
    entries.sort_by_key(|e| e.epoch);
    let mut trained_acc = seed_acc;
    for e in &entries {
        let cfg = ServeConfig { ckpt: Some(e.path.clone()), ..scfg.clone() };
        let (acc, j) =
            serve_one(format!("epoch {}", e.epoch), e.val_acc, &cfg)?;
        rows.push(j);
        trained_acc = acc;
    }
    drop(serve_one); // release the table borrow before rendering it

    let improvement = trained_acc - seed_acc;
    let pass = improvement > 0.05;
    let e2e = obj(vec![
        ("dataset", s(name)),
        ("train_epochs", num(epochs as f64)),
        ("seed_accuracy", num(seed_acc)),
        ("trained_accuracy", num(trained_acc)),
        ("improvement", num(improvement)),
        ("best_train_val_acc", num(treport.best_val_acc)),
        ("pass", Json::Bool(pass)),
        ("runs", arr(rows.clone())),
    ]);
    std::fs::write(
        results_dir().join("e2e_accuracy.json"),
        e2e.to_string_pretty(),
    )?;
    println!("[exp] wrote results/e2e_accuracy.json");

    let md = format!(
        "# Train → checkpoint → serve: accuracy vs latency ({name})\n\n\
         Host trainer, {epochs} epochs, one checkpoint per epoch \
         (`{}`); each row replays the same closed-loop Zipf trace \
         ({} clients x {} requests) through the host executor with \
         that row's parameters installed.\n\n{}\n\
         Seed-parameter accuracy {} → trained accuracy {} \
         (improvement {:+.1}%).\n",
        dir.display(),
        lcfg.clients,
        lcfg.requests_per_client,
        table.to_markdown(),
        pct(seed_acc),
        pct(trained_acc),
        improvement * 100.0,
    );
    write_results(
        "ckpt",
        &md,
        &obj(vec![
            ("seed_accuracy", num(seed_acc)),
            ("trained_accuracy", num(trained_acc)),
            ("runs", arr(rows)),
        ]),
    )?;

    if !pass {
        bail!(
            "e2e accuracy gate failed: trained {trained_acc:.4} vs seed \
             {seed_acc:.4} (improvement {improvement:+.4} <= 0.05)"
        );
    }
    Ok(())
}
