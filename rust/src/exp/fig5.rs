//! Figure 5 — the headline design-space sweep: every root partitioning
//! policy (Table 1) x intra-community probability p x dataset, with
//! four metrics per cell (final val accuracy, per-epoch speedup,
//! epochs-to-converge ratio, total training speedup), normalized to
//! the uniform-random baseline (RAND-ROOTS & p = 0.5).
//!
//! Writes results/fig5.{md,json}; fig6/fig7 re-read the JSON.

use anyhow::Result;

use crate::config::{BatchPolicy, TrainConfig};
use crate::train::Method;
use crate::util::json::{num, obj, s, Json};

use super::common::*;

pub fn datasets() -> Vec<&'static str> {
    if fast() {
        vec!["reddit_sim"]
    } else if quick() {
        vec!["reddit_sim", "products_sim"]
    } else {
        vec!["reddit_sim", "igb_sim", "products_sim", "papers_sim"]
    }
}

pub fn run(ctx: &mut Ctx) -> Result<()> {
    let cfg = TrainConfig { max_epochs: max_epochs(), ..Default::default() };
    let mut md = String::from("# Figure 5 — COMM-RAND knob sweep\n\n");
    let mut json_ds = Vec::new();

    for ds_name in datasets() {
        let (p, ds) = ctx.dataset(ds_name)?;
        println!("[fig5] {ds_name}: sweeping {} policies x {} p-values",
                 root_grid().len(), p_grid().len());
        let mut cells: Vec<(String, f64, Agg)> = Vec::new();
        for roots in root_grid() {
            for p_intra in p_grid() {
                let pol = BatchPolicy { roots, p_intra };
                let reports = ctx.run_seeds(
                    &p, &ds, &Method::CommRand(pol.clone()), &cfg)?;
                let agg = aggregate(&reports);
                println!(
                    "[fig5]   {:<28} acc {:.4} ep-mod {:.5}s conv {:.1}",
                    pol.label(), agg.val_acc, agg.epoch_modeled_s,
                    agg.converged_epochs
                );
                cells.push((pol.label(), p_intra, agg));
            }
        }
        let base = cells
            .iter()
            .find(|(l, _, _)| l.starts_with("RAND-ROOTS+p0.50"))
            .map(|(_, _, a)| {
                (a.epoch_modeled_s, a.converged_epochs, a.total_modeled_s,
                 a.val_acc)
            })
            .unwrap();

        md.push_str(&format!("\n## {ds_name}\n\n"));
        let mut t = Table::new(&[
            "policy", "p", "val acc", "Δacc (pts)", "per-epoch speedup",
            "epochs ratio", "total speedup",
        ]);
        let mut jrows = Vec::new();
        for (label, p_intra, a) in &cells {
            t.row(vec![
                label.clone(),
                format!("{p_intra:.1}"),
                f4(a.val_acc),
                f2((a.val_acc - base.3) * 100.0),
                format!("{:.2}x", base.0 / a.epoch_modeled_s),
                f2(a.converged_epochs / base.1),
                format!("{:.2}x", base.2 / a.total_modeled_s),
            ]);
            jrows.push(obj(vec![
                ("policy", s(label)),
                ("p", num(*p_intra)),
                ("val_acc", num(a.val_acc)),
                ("epoch_modeled_s", num(a.epoch_modeled_s)),
                ("epoch_wall_s", num(a.epoch_wall_s)),
                ("converged_epochs", num(a.converged_epochs)),
                ("total_modeled_s", num(a.total_modeled_s)),
                ("input_bytes", num(a.input_bytes)),
                ("labels_per_batch", num(a.labels_per_batch)),
                ("l2_miss", num(a.l2_miss)),
            ]));
        }
        md.push_str(&t.to_markdown());
        json_ds.push((ds_name.to_string(), Json::Arr(jrows)));
    }

    let json = Json::Obj(json_ds.into_iter().collect());
    write_results("fig5", &md, &json)
}

/// Load fig5.json, running the sweep first if missing.
pub fn load_or_run(ctx: &mut Ctx) -> Result<Json> {
    let path = results_dir().join("fig5.json");
    if !path.exists() {
        run(ctx)?;
    }
    Json::parse_file(&path)
}
