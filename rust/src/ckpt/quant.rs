//! Checkpoint quantization: scale-and-round f32 tensors to i16 (NNUE
//! style) with per-tensor power-of-two scales.
//!
//! Each tensor gets the largest exponent `e ≤ max_exp` such that
//! `max_abs · 2^e ≤ limit`, then `q = round(x · 2^e)`. Power-of-two
//! scales make dequantization `q / 2^e` **exact** in f32 (a 15-bit
//! integer divided by a power of two), so the per-element round-trip
//! error is exactly the rounding error: `|x − q/2^e| ≤ 0.5 / 2^e`.
//!
//! Quantization **fails loudly** instead of saturating: a tensor with
//! a non-finite value, or one whose magnitude exceeds `limit` even at
//! scale 1 (`e = 0`), is unrepresentable and returns an error — a
//! silently clipped weight would serve wrong logits with no
//! diagnostic trail.
//!
//! [`quantize_checkpoint`] applies the pass to a whole checkpoint: the
//! result carries the raw i16 tensors (written to disk as the `i16q`
//! dtype, see [`super::format`]) *and* the exact dequantized f32 view
//! in `params`, so every consumer that wants plain f32 parameters
//! (PJRT `set_params`, the f32 host engine, accuracy eval) works on a
//! quantized checkpoint unchanged.

use anyhow::{bail, Context, Result};

use super::format::Checkpoint;

/// Largest representable quantized weight magnitude (i16).
pub const WEIGHT_LIMIT: i32 = i16::MAX as i32;

/// Largest representable quantized activation magnitude (i8).
pub const FEAT_LIMIT: i32 = i8::MAX as i32;

/// Exponent cap for weight tensors (scale ≤ 2¹⁴, step ≥ 2⁻¹⁴).
pub const WEIGHT_MAX_EXP: u32 = 14;

/// Exponent cap for activation quantization (scale ≤ 2⁶). Kept low so
/// the combined weight×activation scale stays far from the i32
/// accumulator range.
pub const FEAT_MAX_EXP: u32 = 6;

/// One quantized tensor: `i16` values at scale `2^exp`.
#[derive(Clone, Debug, PartialEq)]
pub struct QuantTensor {
    /// Quantized values, same layout as the source tensor.
    pub q: Vec<i16>,
    /// Power-of-two scale exponent: real value = `q / 2^exp`.
    pub exp: u32,
}

impl QuantTensor {
    /// The multiplicative scale `2^exp` (exact in f32 for all valid
    /// exponents).
    pub fn scale(&self) -> f32 {
        (1u64 << self.exp) as f32
    }

    /// Exact f32 dequantization (`q / 2^exp` is representable: ≤ 15
    /// significant bits over a power-of-two denominator).
    pub fn dequant(&self) -> Vec<f32> {
        let inv = 1.0 / self.scale();
        self.q.iter().map(|&v| v as f32 * inv).collect()
    }
}

/// Largest exponent `e ≤ max_exp` with `max_abs · 2^e ≤ limit`.
///
/// Errors on non-finite `max_abs` and on `max_abs > limit` (the tensor
/// is unrepresentable even at scale 1 — the caller gets a loud
/// failure, never a silent saturation).
pub fn pick_exp(max_abs: f32, limit: i32, max_exp: u32) -> Result<u32> {
    if !max_abs.is_finite() {
        bail!("cannot quantize: non-finite magnitude {max_abs}");
    }
    if max_abs > limit as f32 {
        bail!(
            "cannot quantize: magnitude {max_abs} exceeds the integer \
             range ±{limit} at scale 1 (refusing to saturate)"
        );
    }
    let mut e = 0u32;
    while e < max_exp && max_abs * ((1u64 << (e + 1)) as f32) <= limit as f32
    {
        e += 1;
    }
    Ok(e)
}

/// Integer division rounding half away from zero (`round(a / d)` for
/// positive `d`). The quantized executors use it for the
/// closed-neighborhood mean so every kernel variant — which already
/// agrees bitwise on the accumulators — also agrees on the averaged
/// activations.
pub fn rounded_div(a: i32, d: i32) -> i32 {
    debug_assert!(d > 0);
    if a >= 0 {
        (a + d / 2) / d
    } else {
        (a - d / 2) / d
    }
}

/// Quantize one tensor to i16 at the best power-of-two scale for its
/// magnitude. Errors (rather than saturating) on non-finite or
/// out-of-range input.
pub fn quantize_tensor(
    data: &[f32],
    limit: i32,
    max_exp: u32,
) -> Result<QuantTensor> {
    let mut max_abs = 0f32;
    for &x in data {
        if !x.is_finite() {
            bail!("cannot quantize: non-finite element {x}");
        }
        max_abs = max_abs.max(x.abs());
    }
    let exp = pick_exp(max_abs, limit, max_exp)?;
    let scale = (1u64 << exp) as f32;
    let mut q = Vec::with_capacity(data.len());
    for &x in data {
        let r = (x * scale).round();
        // by construction |x|·scale ≤ limit, so round() stays in
        // range; this guards float-edge surprises loudly
        if r.abs() > limit as f32 {
            bail!(
                "quantized value {r} out of ±{limit} at scale 2^{exp} \
                 (input {x})"
            );
        }
        q.push(r as i16);
    }
    Ok(QuantTensor { q, exp })
}

/// Quantize every tensor of a checkpoint to the on-disk `i16q` dtype.
///
/// The returned checkpoint shares `meta` (same shapes, same community
/// fence), stores the raw i16 tensors in `quant`, and replaces
/// `params` with the **exact dequantized** f32 view — so shape
/// validation, accuracy evaluation and non-quantized executors keep
/// working on it unchanged.
pub fn quantize_checkpoint(ck: &Checkpoint) -> Result<Checkpoint> {
    let mut quant = Vec::with_capacity(ck.params.len());
    let mut params = Vec::with_capacity(ck.params.len());
    for (i, p) in ck.params.iter().enumerate() {
        let qt = quantize_tensor(p, WEIGHT_LIMIT, WEIGHT_MAX_EXP)
            .with_context(|| format!("quantizing checkpoint tensor {i}"))?;
        params.push(qt.dequant());
        quant.push(qt);
    }
    Ok(Checkpoint { meta: ck.meta.clone(), params, quant: Some(quant) })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_error_is_bounded_by_half_a_step() {
        let data: Vec<f32> =
            (0..257).map(|i| (i as f32 * 0.37 - 40.0).sin() * 3.0).collect();
        let qt = quantize_tensor(&data, WEIGHT_LIMIT, WEIGHT_MAX_EXP).unwrap();
        let back = qt.dequant();
        let bound = 0.5 / qt.scale();
        for (i, (&x, &y)) in data.iter().zip(&back).enumerate() {
            assert!(
                (x - y).abs() <= bound,
                "element {i}: |{x} - {y}| > {bound}"
            );
        }
    }

    #[test]
    fn scale_maximizes_precision_within_range() {
        // max_abs 3.0 with limit 32767: 3·2^13 = 24576 fits,
        // 3·2^14 = 49152 does not → exp 13; a tiny tensor pins to the
        // exp cap instead
        assert_eq!(pick_exp(3.0, WEIGHT_LIMIT, WEIGHT_MAX_EXP).unwrap(), 13);
        assert_eq!(pick_exp(1e-9, WEIGHT_LIMIT, WEIGHT_MAX_EXP).unwrap(), 14);
        // all-zero tensors quantize at the cap (every q is 0)
        let qt = quantize_tensor(&[0.0; 8], WEIGHT_LIMIT, WEIGHT_MAX_EXP)
            .unwrap();
        assert_eq!(qt.exp, WEIGHT_MAX_EXP);
        assert!(qt.q.iter().all(|&v| v == 0));
        // feature quantization respects its own limit/cap
        assert_eq!(pick_exp(100.0, FEAT_LIMIT, FEAT_MAX_EXP).unwrap(), 0);
        assert_eq!(pick_exp(0.5, FEAT_LIMIT, FEAT_MAX_EXP).unwrap(), 6);
    }

    #[test]
    fn out_of_range_fails_loudly_instead_of_saturating() {
        let err =
            quantize_tensor(&[1.0, 40000.0], WEIGHT_LIMIT, WEIGHT_MAX_EXP)
                .unwrap_err();
        assert!(format!("{err:#}").contains("refusing to saturate"));
        // features hit their smaller limit much earlier
        assert!(quantize_tensor(&[200.0], FEAT_LIMIT, FEAT_MAX_EXP).is_err());
    }

    #[test]
    fn non_finite_values_are_refused() {
        for bad in [f32::NAN, f32::INFINITY, f32::NEG_INFINITY] {
            assert!(
                quantize_tensor(&[0.0, bad], WEIGHT_LIMIT, WEIGHT_MAX_EXP)
                    .is_err()
            );
        }
    }

    #[test]
    fn rounded_div_rounds_half_away_from_zero() {
        assert_eq!(rounded_div(7, 2), 4);
        assert_eq!(rounded_div(-7, 2), -4);
        assert_eq!(rounded_div(6, 3), 2);
        assert_eq!(rounded_div(-6, 3), -2);
        assert_eq!(rounded_div(0, 5), 0);
        assert_eq!(rounded_div(1, 3), 0);
        assert_eq!(rounded_div(2, 3), 1);
    }

    #[test]
    fn dequant_is_exact_for_quantized_values() {
        let qt = QuantTensor { q: vec![-32767, -1, 0, 1, 12345], exp: 9 };
        let d = qt.dequant();
        // re-quantizing at the same scale reproduces q bit-for-bit
        for (&q, &x) in qt.q.iter().zip(&d) {
            assert_eq!((x * qt.scale()).round() as i16, q);
            assert_eq!(x, q as f32 / 512.0);
        }
    }
}
