//! On-disk checkpoint format: a versioned, CRC-checked binary record
//! of trained parameters plus the metadata needed to decide whether a
//! checkpoint may be served at all.
//!
//! Layout (all integers little-endian):
//!
//! ```text
//! offset  size        contents
//! 0       4           magic  b"CRCK"
//! 4       4           format version (u32, currently 1)
//! 8       4           header length H (u32, bytes)
//! 12      H           JSON header (dataset, model, epoch, val metrics,
//!                     seed, policy label, community fingerprint,
//!                     parameter shapes, hot-node list; quantized
//!                     checkpoints add `dtype` + per-tensor
//!                     `scale_exp`)
//! 12+H    payload     parameter payload, tensors concatenated in
//!                     shape order: f32 LE (default dtype), or i16 LE
//!                     when the header declares `dtype: "i16q"`
//! end-4   4           CRC-32 (IEEE) over every preceding byte
//! ```
//!
//! The `dtype`/`scale_exp` header fields are emitted **only** for
//! quantized checkpoints, so every pre-existing f32 file re-encodes
//! byte-identically; a reader that meets a dtype tag it does not know
//! refuses the file instead of misinterpreting the payload.
//!
//! Two validation layers protect the serving side:
//!
//! * **Integrity** — [`Checkpoint::decode`] refuses truncated files,
//!   bad magic, unknown format versions, CRC mismatches and payloads
//!   whose length disagrees with the declared shapes.
//! * **Version fencing** — the header records a fingerprint of the
//!   Louvain labeling the parameters were trained against
//!   ([`community_fingerprint`]). [`Checkpoint::validate_against`]
//!   rejects a checkpoint whose fingerprint does not match the serving
//!   dataset: after a re-detection or re-reorder, node ids mean
//!   different things and silently serving the old parameters would be
//!   wrong in a way no shape check can catch.

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::graph::Dataset;
use crate::util::json::{arr, arr_f64, num, obj, s, Json};

use super::quant::QuantTensor;

/// File magic: "CRCK" (Comm-Rand ChecKpoint).
pub const MAGIC: [u8; 4] = *b"CRCK";

/// Current format version; bumped on any layout change.
pub const FORMAT_VERSION: u32 = 1;

/// CRC-32 (IEEE 802.3, reflected, poly 0xEDB88320) — the same
/// polynomial zlib/gzip use, computed bitwise (the payloads are small
/// enough that a lookup table buys nothing).
pub fn crc32(data: &[u8]) -> u32 {
    let mut crc = 0xFFFF_FFFFu32;
    for &b in data {
        crc ^= b as u32;
        for _ in 0..8 {
            let mask = (crc & 1).wrapping_neg();
            crc = (crc >> 1) ^ (0xEDB8_8320 & mask);
        }
    }
    !crc
}

/// FNV-1a (64-bit) over the community labeling: `num_comms`, the label
/// count, then every per-node label in node order. Any change to the
/// detection output or the node permutation changes the fingerprint,
/// which is exactly the property the checkpoint fence needs.
pub fn community_fingerprint(community: &[u32], num_comms: usize) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    let mut mix = |x: u64| {
        for byte in x.to_le_bytes() {
            h ^= byte as u64;
            h = h.wrapping_mul(0x0000_0100_0000_01B3);
        }
    };
    mix(num_comms as u64);
    mix(community.len() as u64);
    for &c in community {
        mix(c as u64);
    }
    h
}

/// Structural hot-node proxy stored in checkpoint metadata: the `k`
/// highest-degree nodes (ties broken by lower id). High-degree nodes
/// appear in many sampled frontiers regardless of the request mix, so
/// they are the rows a cold serving cache benefits most from holding
/// before the first request lands (`serve bench cache_warm=1`).
pub fn degree_hot_nodes(ds: &Dataset, k: usize) -> Vec<u32> {
    let n = ds.n();
    let mut order: Vec<u32> = (0..n as u32).collect();
    order.sort_by_key(|&v| (std::cmp::Reverse(ds.csr.degree(v)), v));
    order.truncate(k.min(n));
    order
}

/// Everything the header records about a checkpoint besides the raw
/// parameter payload.
#[derive(Clone, Debug)]
pub struct CkptMeta {
    /// Dataset the parameters were trained on (preset name).
    pub dataset: String,
    /// Model family the parameter layout belongs to (`sage` / `gcn` /
    /// `gat` for PJRT artifacts, `host-sgc` for the host reference
    /// model).
    pub model: String,
    /// Label of the batching policy the run used.
    pub policy: String,
    /// Training epoch this checkpoint was taken at (0-based).
    pub epoch: usize,
    /// Validation accuracy at `epoch` (retention keeps the best).
    pub val_acc: f64,
    /// Validation loss at `epoch`.
    pub val_loss: f64,
    /// Training seed, for provenance.
    pub seed: u64,
    /// [`community_fingerprint`] of the Louvain labeling the run
    /// trained against.
    pub comm_fp: u64,
    /// `num_comms` of that labeling (redundant with the fingerprint,
    /// kept for readable error messages).
    pub num_comms: usize,
    /// Shape of every parameter tensor, in payload order.
    pub shapes: Vec<Vec<usize>>,
    /// Hot-node list for serving cache warmup (may be empty).
    pub hot_nodes: Vec<u32>,
}

impl CkptMeta {
    /// Total f32 elements across all parameter tensors.
    pub fn num_elements(&self) -> usize {
        self.shapes.iter().map(|sh| sh.iter().product::<usize>()).sum()
    }

    /// Run-level template for a training run on `ds`: fingerprint and
    /// hot-node list filled in, per-epoch fields (`epoch`, `val_acc`,
    /// `val_loss`) zeroed for the caller to stamp at each write.
    pub fn for_run(
        ds: &Dataset,
        model: &str,
        policy: &str,
        seed: u64,
        shapes: Vec<Vec<usize>>,
    ) -> CkptMeta {
        CkptMeta {
            dataset: ds.name.clone(),
            model: model.to_string(),
            policy: policy.to_string(),
            epoch: 0,
            val_acc: 0.0,
            val_loss: 0.0,
            seed,
            comm_fp: community_fingerprint(&ds.community, ds.num_comms),
            num_comms: ds.num_comms,
            shapes,
            hot_nodes: degree_hot_nodes(ds, 1024),
        }
    }
}

/// One decoded checkpoint: metadata + parameter tensors.
#[derive(Clone, Debug)]
pub struct Checkpoint {
    /// Header metadata.
    pub meta: CkptMeta,
    /// Parameter tensors, flattened row-major, in `meta.shapes` order.
    /// For a quantized checkpoint this is the **exact dequantized**
    /// view of `quant` (`q / 2^exp`), so f32 consumers need no special
    /// casing.
    pub params: Vec<Vec<f32>>,
    /// Raw quantized tensors when this checkpoint has dtype `i16q`
    /// (produced by [`super::quant::quantize_checkpoint`] or read back
    /// from disk); `None` for plain f32 checkpoints.
    pub quant: Option<Vec<QuantTensor>>,
}

impl Checkpoint {
    /// Build a checkpoint, deriving `shapes` from `params` shapes given
    /// explicitly (they cannot be recovered from flat vectors).
    pub fn new(meta: CkptMeta, params: Vec<Vec<f32>>) -> Result<Checkpoint> {
        if meta.shapes.len() != params.len() {
            bail!(
                "checkpoint meta declares {} tensors, got {}",
                meta.shapes.len(),
                params.len()
            );
        }
        for (i, (sh, p)) in meta.shapes.iter().zip(&params).enumerate() {
            let want: usize = sh.iter().product();
            if want != p.len() {
                bail!(
                    "checkpoint tensor {i} has {} elements, shape {sh:?} \
                     wants {want}",
                    p.len()
                );
            }
        }
        Ok(Checkpoint { meta, params, quant: None })
    }

    /// Payload dtype tag: `"f32"` (default) or `"i16q"` (quantized).
    pub fn dtype(&self) -> &'static str {
        if self.quant.is_some() {
            "i16q"
        } else {
            "f32"
        }
    }

    fn header_json(&self) -> Json {
        let m = &self.meta;
        let mut fields = vec![
            ("dataset", s(&m.dataset)),
            ("model", s(&m.model)),
            ("policy", s(&m.policy)),
            ("epoch", num(m.epoch as f64)),
            ("val_acc", num(m.val_acc)),
            ("val_loss", num(m.val_loss)),
            // u64 values (seed, fingerprint) are stored as hex strings:
            // JSON numbers are f64 and would silently round above 2^53
            ("seed", s(&format!("{:016x}", m.seed))),
            ("comm_fp", s(&format!("{:016x}", m.comm_fp))),
            ("num_comms", num(m.num_comms as f64)),
            (
                "shapes",
                arr(m
                    .shapes
                    .iter()
                    .map(|sh| {
                        arr_f64(&sh.iter().map(|&d| d as f64).collect::<Vec<_>>())
                    })
                    .collect()),
            ),
            (
                "hot_nodes",
                arr_f64(&m.hot_nodes.iter().map(|&v| v as f64).collect::<Vec<_>>()),
            ),
        ];
        // emitted only for quantized checkpoints, so plain f32 files
        // keep their exact pre-quantization byte layout
        if let Some(q) = &self.quant {
            fields.push(("dtype", s(self.dtype())));
            fields.push((
                "scale_exp",
                arr_f64(
                    &q.iter().map(|t| t.exp as f64).collect::<Vec<_>>(),
                ),
            ));
        }
        obj(fields)
    }

    /// Serialize to the on-disk byte layout (see module docs). The
    /// payload is f32 LE, or i16 LE for `i16q` checkpoints.
    pub fn encode(&self) -> Vec<u8> {
        let header = self.header_json().to_string_pretty();
        let elem = if self.quant.is_some() { 2 } else { 4 };
        let payload_len: usize =
            self.params.iter().map(|p| p.len() * elem).sum();
        let mut out =
            Vec::with_capacity(16 + header.len() + payload_len);
        out.extend_from_slice(&MAGIC);
        out.extend_from_slice(&FORMAT_VERSION.to_le_bytes());
        out.extend_from_slice(&(header.len() as u32).to_le_bytes());
        out.extend_from_slice(header.as_bytes());
        if let Some(quant) = &self.quant {
            for t in quant {
                for &v in &t.q {
                    out.extend_from_slice(&v.to_le_bytes());
                }
            }
        } else {
            for p in &self.params {
                for &x in p {
                    out.extend_from_slice(&x.to_le_bytes());
                }
            }
        }
        let crc = crc32(&out);
        out.extend_from_slice(&crc.to_le_bytes());
        out
    }

    /// Parse and fully validate the byte layout (magic, version, CRC,
    /// header, payload size).
    pub fn decode(bytes: &[u8]) -> Result<Checkpoint> {
        if bytes.len() < 16 {
            bail!("truncated checkpoint: {} bytes", bytes.len());
        }
        if bytes[0..4] != MAGIC {
            bail!("not a checkpoint file (bad magic)");
        }
        let ver = u32::from_le_bytes(bytes[4..8].try_into().unwrap());
        if ver != FORMAT_VERSION {
            bail!(
                "unsupported checkpoint format version {ver} \
                 (this build reads {FORMAT_VERSION})"
            );
        }
        let body = &bytes[..bytes.len() - 4];
        let stored =
            u32::from_le_bytes(bytes[bytes.len() - 4..].try_into().unwrap());
        let computed = crc32(body);
        if stored != computed {
            bail!(
                "checkpoint CRC mismatch: stored {stored:08x}, computed \
                 {computed:08x} (corrupt or truncated file)"
            );
        }
        let hlen =
            u32::from_le_bytes(bytes[8..12].try_into().unwrap()) as usize;
        if 12 + hlen > body.len() {
            bail!("truncated checkpoint: header overruns file");
        }
        let header_str = std::str::from_utf8(&body[12..12 + hlen])
            .context("checkpoint header is not UTF-8")?;
        let h = Json::parse(header_str).context("checkpoint header JSON")?;

        let hex_u64 = |key: &str| -> Result<u64> {
            let v = h.get(key)?.as_str()?;
            u64::from_str_radix(v, 16)
                .with_context(|| format!("bad hex field {key}={v:?}"))
        };
        let shapes: Vec<Vec<usize>> = h
            .get("shapes")?
            .as_arr()?
            .iter()
            .map(|sh| {
                sh.as_arr()?.iter().map(|d| d.as_usize()).collect::<Result<_>>()
            })
            .collect::<Result<_>>()?;
        let hot_nodes: Vec<u32> = h
            .get("hot_nodes")?
            .as_arr()?
            .iter()
            .map(|v| Ok(v.as_usize()? as u32))
            .collect::<Result<_>>()?;
        let meta = CkptMeta {
            dataset: h.get("dataset")?.as_str()?.to_string(),
            model: h.get("model")?.as_str()?.to_string(),
            policy: h.get("policy")?.as_str()?.to_string(),
            epoch: h.get("epoch")?.as_usize()?,
            val_acc: h.get("val_acc")?.as_f64()?,
            val_loss: h.get("val_loss")?.as_f64()?,
            seed: hex_u64("seed")?,
            comm_fp: hex_u64("comm_fp")?,
            num_comms: h.get("num_comms")?.as_usize()?,
            shapes,
            hot_nodes,
        };

        // dtype is absent on plain f32 checkpoints (pre-quantization
        // files stay readable and byte-stable); an unknown tag is a
        // hard error — guessing the payload encoding would be worse
        // than refusing the file
        let dtype = match h.opt("dtype") {
            None => "f32".to_string(),
            Some(d) => d.as_str()?.to_string(),
        };
        let elem = match dtype.as_str() {
            "f32" => 4usize,
            "i16q" => 2usize,
            other => bail!(
                "unknown checkpoint dtype {other:?} (this build reads \
                 f32 and i16q); refusing to guess the payload encoding"
            ),
        };

        let payload = &body[12 + hlen..];
        let want = meta.num_elements() * elem;
        if payload.len() != want {
            bail!(
                "checkpoint payload is {} bytes, shapes declare {want} \
                 (truncated or shape-corrupt file)",
                payload.len()
            );
        }
        if dtype == "i16q" {
            let exps: Vec<u32> = h
                .get("scale_exp")?
                .as_arr()?
                .iter()
                .map(|v| Ok(v.as_usize()? as u32))
                .collect::<Result<_>>()?;
            if exps.len() != meta.shapes.len() {
                bail!(
                    "checkpoint declares {} scale exponents for {} \
                     tensors",
                    exps.len(),
                    meta.shapes.len()
                );
            }
            let mut quant = Vec::with_capacity(meta.shapes.len());
            let mut params = Vec::with_capacity(meta.shapes.len());
            let mut off = 0usize;
            for (sh, &exp) in meta.shapes.iter().zip(&exps) {
                let n: usize = sh.iter().product();
                let mut q = Vec::with_capacity(n);
                for _ in 0..n {
                    q.push(i16::from_le_bytes(
                        payload[off..off + 2].try_into().unwrap(),
                    ));
                    off += 2;
                }
                let t = QuantTensor { q, exp };
                params.push(t.dequant());
                quant.push(t);
            }
            return Ok(Checkpoint { meta, params, quant: Some(quant) });
        }
        let mut params = Vec::with_capacity(meta.shapes.len());
        let mut off = 0usize;
        for sh in &meta.shapes {
            let n: usize = sh.iter().product();
            let mut t = Vec::with_capacity(n);
            for _ in 0..n {
                t.push(f32::from_le_bytes(
                    payload[off..off + 4].try_into().unwrap(),
                ));
                off += 4;
            }
            params.push(t);
        }
        Ok(Checkpoint { meta, params, quant: None })
    }

    /// Load and validate a checkpoint file.
    pub fn load(path: &Path) -> Result<Checkpoint> {
        let bytes = std::fs::read(path)
            .with_context(|| format!("reading checkpoint {}", path.display()))?;
        Checkpoint::decode(&bytes)
            .with_context(|| format!("decoding checkpoint {}", path.display()))
    }

    /// Write atomically: serialize to a sibling temp file, then rename
    /// over `path`. Readers (the reload watcher, a concurrent `serve
    /// bench`) never observe a half-written checkpoint — they either
    /// see the old file or the complete new one.
    pub fn write_atomic(&self, path: &Path) -> Result<()> {
        let bytes = self.encode();
        let mut tmp = path.as_os_str().to_owned();
        tmp.push(format!(".tmp{}", std::process::id()));
        let tmp = std::path::PathBuf::from(tmp);
        std::fs::write(&tmp, &bytes)
            .with_context(|| format!("writing {}", tmp.display()))?;
        std::fs::rename(&tmp, path).with_context(|| {
            format!("renaming {} -> {}", tmp.display(), path.display())
        })
    }

    /// Version fence: refuse to pair this checkpoint with a dataset
    /// whose community labeling differs from the one it was trained
    /// against (node ids would no longer mean the same thing).
    pub fn validate_against(
        &self,
        community: &[u32],
        num_comms: usize,
    ) -> Result<()> {
        let fp = community_fingerprint(community, num_comms);
        if fp != self.meta.comm_fp {
            bail!(
                "checkpoint community fingerprint {:016x} (dataset {:?}, \
                 {} comms) does not match the serving dataset's {fp:016x} \
                 ({num_comms} comms): the Louvain labeling/reorder \
                 changed since training; retrain or regenerate the data",
                self.meta.comm_fp,
                self.meta.dataset,
                self.meta.num_comms,
            );
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_meta() -> CkptMeta {
        CkptMeta {
            dataset: "tiny".into(),
            model: "host-sgc".into(),
            policy: "host".into(),
            epoch: 3,
            val_acc: 0.75,
            val_loss: 0.9,
            seed: 0xDEAD_BEEF_0123_4567,
            comm_fp: 0xABCD_EF00_1122_3344,
            num_comms: 12,
            shapes: vec![vec![4, 3], vec![3]],
            hot_nodes: vec![5, 1, 9],
        }
    }

    fn sample_ckpt() -> Checkpoint {
        let params = vec![
            (0..12).map(|i| i as f32 * 0.25 - 1.0).collect(),
            vec![0.5, -0.5, 3.25],
        ];
        Checkpoint::new(sample_meta(), params).unwrap()
    }

    #[test]
    fn crc32_matches_known_vectors() {
        // standard test vector for CRC-32/IEEE
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
    }

    #[test]
    fn roundtrip_is_bitwise_exact() {
        let ck = sample_ckpt();
        let bytes = ck.encode();
        let back = Checkpoint::decode(&bytes).unwrap();
        assert_eq!(back.meta.dataset, "tiny");
        assert_eq!(back.meta.epoch, 3);
        assert_eq!(back.meta.seed, 0xDEAD_BEEF_0123_4567);
        assert_eq!(back.meta.comm_fp, 0xABCD_EF00_1122_3344);
        assert_eq!(back.meta.shapes, ck.meta.shapes);
        assert_eq!(back.meta.hot_nodes, vec![5, 1, 9]);
        assert_eq!(back.params.len(), ck.params.len());
        for (a, b) in ck.params.iter().zip(&back.params) {
            let ab: Vec<u32> = a.iter().map(|x| x.to_bits()).collect();
            let bb: Vec<u32> = b.iter().map(|x| x.to_bits()).collect();
            assert_eq!(ab, bb, "payload must round-trip bit-for-bit");
        }
        // re-encoding the decoded checkpoint reproduces the same bytes
        assert_eq!(back.encode(), bytes);
    }

    #[test]
    fn truncated_files_are_rejected() {
        let bytes = sample_ckpt().encode();
        for cut in [0, 3, 8, 15, bytes.len() / 2, bytes.len() - 1] {
            assert!(
                Checkpoint::decode(&bytes[..cut]).is_err(),
                "decode accepted a file truncated to {cut} bytes"
            );
        }
    }

    #[test]
    fn corrupt_crc_is_rejected() {
        let mut bytes = sample_ckpt().encode();
        // flip one payload byte: CRC catches it
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0x40;
        let err = Checkpoint::decode(&bytes).unwrap_err();
        assert!(format!("{err:#}").contains("CRC"), "{err:#}");
        // flip it back, corrupt the stored CRC itself
        bytes[mid] ^= 0x40;
        let last = bytes.len() - 1;
        bytes[last] ^= 0x01;
        assert!(Checkpoint::decode(&bytes).is_err());
    }

    #[test]
    fn bad_magic_and_version_are_rejected() {
        let mut bytes = sample_ckpt().encode();
        bytes[0] = b'X';
        assert!(Checkpoint::decode(&bytes).is_err());
        // fix magic, bump version (and re-CRC so only the version is bad)
        let ck = sample_ckpt();
        let mut raw = ck.encode();
        raw[4] = 99;
        let body_len = raw.len() - 4;
        let crc = crc32(&raw[..body_len]).to_le_bytes();
        raw[body_len..].copy_from_slice(&crc);
        let err = Checkpoint::decode(&raw).unwrap_err();
        assert!(format!("{err:#}").contains("version"), "{err:#}");
    }

    #[test]
    fn fingerprint_mismatch_is_rejected() {
        let community = vec![0u32, 0, 1, 1, 2];
        let fp = community_fingerprint(&community, 3);
        let mut meta = sample_meta();
        meta.comm_fp = fp;
        let ck = Checkpoint::new(meta, vec![vec![0.0; 12], vec![0.0; 3]])
            .unwrap();
        ck.validate_against(&community, 3).unwrap();
        // different labeling → fence trips
        let other = vec![0u32, 1, 0, 1, 2];
        let err = ck.validate_against(&other, 3).unwrap_err();
        assert!(format!("{err:#}").contains("fingerprint"), "{err:#}");
        // different num_comms → fence trips too
        assert!(ck.validate_against(&community, 4).is_err());
    }

    #[test]
    fn fingerprint_is_order_sensitive() {
        let a = community_fingerprint(&[0, 1, 2], 3);
        let b = community_fingerprint(&[2, 1, 0], 3);
        assert_ne!(a, b);
        assert_eq!(a, community_fingerprint(&[0, 1, 2], 3));
    }

    #[test]
    fn shape_payload_mismatch_is_rejected_at_build() {
        let meta = sample_meta();
        assert!(Checkpoint::new(meta.clone(), vec![vec![0.0; 5]]).is_err());
        assert!(Checkpoint::new(
            meta,
            vec![vec![0.0; 11], vec![0.0; 3]]
        )
        .is_err());
    }

    #[test]
    fn quantized_checkpoint_roundtrips_exactly() {
        let ck = crate::ckpt::quant::quantize_checkpoint(&sample_ckpt())
            .unwrap();
        assert_eq!(ck.dtype(), "i16q");
        let bytes = ck.encode();
        let back = Checkpoint::decode(&bytes).unwrap();
        assert_eq!(back.dtype(), "i16q");
        assert_eq!(back.quant, ck.quant, "i16 payload round-trips exactly");
        // the dequantized f32 view round-trips bitwise too
        for (a, b) in ck.params.iter().zip(&back.params) {
            let ab: Vec<u32> = a.iter().map(|x| x.to_bits()).collect();
            let bb: Vec<u32> = b.iter().map(|x| x.to_bits()).collect();
            assert_eq!(ab, bb);
        }
        assert_eq!(back.encode(), bytes, "re-encode is byte-identical");
        // the i16 payload is half the f32 payload
        let f32_bytes = sample_ckpt().encode();
        assert!(bytes.len() < f32_bytes.len());
    }

    #[test]
    fn plain_f32_headers_carry_no_dtype_field() {
        let bytes = sample_ckpt().encode();
        let hlen =
            u32::from_le_bytes(bytes[8..12].try_into().unwrap()) as usize;
        let header = std::str::from_utf8(&bytes[12..12 + hlen]).unwrap();
        assert!(
            !header.contains("dtype") && !header.contains("scale_exp"),
            "f32 checkpoints must keep the pre-quantization header: \
             {header}"
        );
    }

    #[test]
    fn unknown_dtype_tag_is_refused() {
        let ck = crate::ckpt::quant::quantize_checkpoint(&sample_ckpt())
            .unwrap();
        let mut bytes = ck.encode();
        // patch the 4-byte dtype string to same-length garbage and
        // re-CRC, so *only* the dtype tag is wrong
        let hlen =
            u32::from_le_bytes(bytes[8..12].try_into().unwrap()) as usize;
        let header = std::str::from_utf8(&bytes[12..12 + hlen]).unwrap();
        let at = 12 + header.find("i16q").expect("dtype tag in header");
        bytes[at..at + 4].copy_from_slice(b"zz9q");
        let body_len = bytes.len() - 4;
        let crc = crc32(&bytes[..body_len]).to_le_bytes();
        bytes[body_len..].copy_from_slice(&crc);
        let err = Checkpoint::decode(&bytes).unwrap_err();
        assert!(format!("{err:#}").contains("dtype"), "{err:#}");
    }

    #[test]
    fn atomic_write_then_load() {
        let dir = std::env::temp_dir().join("comm_rand_ckpt_fmt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("rt.bin");
        let ck = sample_ckpt();
        ck.write_atomic(&path).unwrap();
        let back = Checkpoint::load(&path).unwrap();
        assert_eq!(back.meta.epoch, ck.meta.epoch);
        assert_eq!(back.params, ck.params);
        std::fs::remove_file(&path).ok();
    }
}
