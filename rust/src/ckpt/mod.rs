//! Checkpoint & parameter-store subsystem: versioned training
//! checkpoints with zero-downtime hot swap into the serving engine.
//!
//! Three pieces bridge the train → serve gap:
//!
//! * [`format`] — the on-disk record: a CRC-checked, versioned binary
//!   layout carrying parameters, layer shapes, training metadata and a
//!   fingerprint of the community labeling the run trained against
//!   ([`format::community_fingerprint`]), so a checkpoint is only
//!   loadable against the matching Louvain labeling/reorder.
//! * [`store`] — [`CheckpointWriter`] hooks the training loop
//!   (`ckpt_dir=` / `ckpt_every=`, atomic rename, retention keeping
//!   best-by-val-acc + latest) and [`ParamStore`] serves immutable
//!   `Arc<ParamVersion>` snapshots to the serving side.
//! * [`quant`] — the NNUE-style quantization pass: f32 → i16 tensors
//!   with per-tensor power-of-two scales (loud failure on range
//!   overflow), stored on disk as the `i16q` dtype and served through
//!   the integer SIMD kernels in [`crate::runtime::kernels`].
//! * [`watch`] — the reload watcher the engine runs during a serving
//!   run: poll the checkpoint directory, validate + stage new
//!   versions, and hand them to the executor, which swaps them in
//!   between micro-batches (per-shard `param_version` / `swaps`
//!   counters in the `ServeReport` make the swap observable).
//!
//! The lifecycle diagram and failure-mode walk-through live in
//! `docs/ARCHITECTURE.md` ("Checkpoint lifecycle & hot-swap").

pub mod format;
pub mod quant;
pub mod store;
pub mod watch;

pub use format::{
    community_fingerprint, degree_hot_nodes, Checkpoint, CkptMeta,
};
pub use quant::{quantize_checkpoint, quantize_tensor, QuantTensor};
pub use store::{
    resolve_checkpoint, CheckpointWriter, ParamStore, ParamVersion,
    Retention, WrittenCkpt,
};
pub use watch::{watch_loop, watch_loop_observed, watch_loop_with, DirWatcher};
