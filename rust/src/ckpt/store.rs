//! Parameter store (serving side) and checkpoint writer (training
//! side).
//!
//! * [`ParamStore`] holds the currently-published parameter set as an
//!   `Arc<ParamVersion>` snapshot. Publishing assigns a monotonically
//!   increasing version number; readers clone the `Arc` and keep
//!   working on their snapshot while a newer version lands — the
//!   zero-downtime half of hot swapping.
//! * [`CheckpointWriter`] is the training-loop hook: write a
//!   checkpoint every `every` epochs (atomic rename via
//!   [`Checkpoint::write_atomic`]) and prune according to the
//!   [`Retention`] policy — by default keeping the best-by-val-acc
//!   checkpoint plus the latest one.

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use anyhow::{bail, Context, Result};

use super::format::{Checkpoint, CkptMeta};

/// One published, immutable parameter snapshot.
#[derive(Clone, Debug)]
pub struct ParamVersion {
    /// Store-assigned version, monotonically increasing from 1.
    pub version: u64,
    /// Parameter tensors (flattened, in `meta.shapes` order). For a
    /// quantized checkpoint these are the exact dequantized values, so
    /// f32 consumers (PJRT `set_params`, accuracy eval) work on every
    /// version unchanged.
    pub params: Vec<Vec<f32>>,
    /// Raw quantized tensors when the source checkpoint has dtype
    /// `i16q` — executors with an integer fast path (the host model's
    /// SIMD kernels) install these instead of `params`.
    pub quant: Option<Vec<crate::ckpt::quant::QuantTensor>>,
    /// The checkpoint metadata this version was published from.
    pub meta: CkptMeta,
    /// File the version was loaded from (for logs/reports).
    pub source: PathBuf,
}

/// Versioned holder of the current parameter snapshot (see module
/// docs). Cheap to read: `current()` is one mutex-guarded `Arc` clone.
#[derive(Debug, Default)]
pub struct ParamStore {
    cur: Mutex<Option<Arc<ParamVersion>>>,
    published: AtomicU64,
}

impl ParamStore {
    /// Empty store: no version published yet (`version()` is 0).
    pub fn new() -> ParamStore {
        ParamStore::default()
    }

    /// Publish a checkpoint as the next parameter version and return
    /// the snapshot.
    pub fn publish(&self, ck: Checkpoint, source: PathBuf) -> Arc<ParamVersion> {
        let version = self.published.fetch_add(1, Ordering::SeqCst) + 1;
        let v = Arc::new(ParamVersion {
            version,
            params: ck.params,
            quant: ck.quant,
            meta: ck.meta,
            source,
        });
        *self.cur.lock().unwrap() = Some(v.clone());
        v
    }

    /// Latest published snapshot, if any.
    pub fn current(&self) -> Option<Arc<ParamVersion>> {
        self.cur.lock().unwrap().clone()
    }

    /// Version of the latest snapshot (0 when nothing is published).
    pub fn version(&self) -> u64 {
        self.current().map(|v| v.version).unwrap_or(0)
    }
}

/// What [`CheckpointWriter`] keeps on disk after each write.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Retention {
    /// Keep the checkpoint with the best validation accuracy plus the
    /// most recent one (they may be the same file). The default.
    BestAndLatest,
    /// Never delete (epoch sweeps, tests).
    All,
}

/// One checkpoint the writer has on disk.
#[derive(Clone, Debug)]
pub struct WrittenCkpt {
    /// File path (inside the writer's directory).
    pub path: PathBuf,
    /// Training epoch of the checkpoint.
    pub epoch: usize,
    /// Validation accuracy recorded in its header.
    pub val_acc: f64,
}

/// Training-loop checkpoint sink: cadence, atomic writes, retention.
pub struct CheckpointWriter {
    dir: PathBuf,
    every: usize,
    retention: Retention,
    entries: Vec<WrittenCkpt>,
}

impl CheckpointWriter {
    /// Create the directory (if needed) and a writer that fires every
    /// `every` epochs (floored at 1).
    pub fn new(
        dir: impl Into<PathBuf>,
        every: usize,
        retention: Retention,
    ) -> Result<CheckpointWriter> {
        let dir = dir.into();
        std::fs::create_dir_all(&dir)
            .with_context(|| format!("creating ckpt dir {}", dir.display()))?;
        Ok(CheckpointWriter {
            dir,
            every: every.max(1),
            entries: Vec::new(),
            retention,
        })
    }

    /// The directory checkpoints land in.
    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Whether the cadence fires at `epoch` (0-based): epochs
    /// `every-1, 2*every-1, ...`, i.e. "every N epochs" counting from
    /// the first.
    pub fn cadence_hit(&self, epoch: usize) -> bool {
        (epoch + 1) % self.every == 0
    }

    /// Write `ck` if the cadence fires at its epoch; returns the path
    /// written, if any.
    pub fn maybe_write(&mut self, ck: &Checkpoint) -> Result<Option<PathBuf>> {
        if !self.cadence_hit(ck.meta.epoch) {
            return Ok(None);
        }
        self.write(ck).map(Some)
    }

    /// Unconditionally write `ck` (atomic rename) and apply retention.
    pub fn write(&mut self, ck: &Checkpoint) -> Result<PathBuf> {
        let path = self.dir.join(format!("ckpt-e{:05}.bin", ck.meta.epoch));
        ck.write_atomic(&path)?;
        // re-writing the same epoch replaces its entry
        self.entries.retain(|e| e.path != path);
        self.entries.push(WrittenCkpt {
            path: path.clone(),
            epoch: ck.meta.epoch,
            val_acc: ck.meta.val_acc,
        });
        self.prune();
        Ok(path)
    }

    /// Retention pass: under [`Retention::BestAndLatest`], delete every
    /// file except the best-val-acc checkpoint (ties → later epoch) and
    /// the latest-epoch one.
    fn prune(&mut self) {
        if self.retention == Retention::All || self.entries.len() <= 1 {
            return;
        }
        let best = self
            .entries
            .iter()
            .max_by(|a, b| {
                a.val_acc
                    .partial_cmp(&b.val_acc)
                    .unwrap_or(std::cmp::Ordering::Equal)
                    .then(a.epoch.cmp(&b.epoch))
            })
            .map(|e| e.path.clone());
        let latest = self
            .entries
            .iter()
            .max_by_key(|e| e.epoch)
            .map(|e| e.path.clone());
        self.entries.retain(|e| {
            let keep = Some(&e.path) == best.as_ref()
                || Some(&e.path) == latest.as_ref();
            if !keep {
                std::fs::remove_file(&e.path).ok();
            }
            keep
        });
    }

    /// Checkpoints currently on disk (post-retention).
    pub fn entries(&self) -> &[WrittenCkpt] {
        &self.entries
    }

    /// The on-disk checkpoint with the best validation accuracy.
    pub fn best(&self) -> Option<&WrittenCkpt> {
        self.entries.iter().max_by(|a, b| {
            a.val_acc
                .partial_cmp(&b.val_acc)
                .unwrap_or(std::cmp::Ordering::Equal)
                .then(a.epoch.cmp(&b.epoch))
        })
    }

    /// The on-disk checkpoint from the latest epoch.
    pub fn latest(&self) -> Option<&WrittenCkpt> {
        self.entries.iter().max_by_key(|e| e.epoch)
    }
}

/// Resolve a `ckpt=` argument and load it in one pass: a file path is
/// loaded as-is; a directory is scanned for `*.bin` checkpoints and
/// the one with the highest epoch wins (what a deployment means by
/// "serve the newest checkpoint in this directory"). Returning the
/// decoded [`Checkpoint`] alongside the path saves the caller a
/// second full read + CRC pass over the winner.
pub fn resolve_checkpoint(path: &Path) -> Result<(PathBuf, Checkpoint)> {
    if path.is_file() {
        let ck = Checkpoint::load(path)?;
        return Ok((path.to_path_buf(), ck));
    }
    if !path.is_dir() {
        bail!("checkpoint path {} does not exist", path.display());
    }
    let mut best: Option<(PathBuf, Checkpoint)> = None;
    for entry in std::fs::read_dir(path)
        .with_context(|| format!("reading ckpt dir {}", path.display()))?
    {
        let p = entry?.path();
        if p.extension().and_then(|e| e.to_str()) != Some("bin") {
            continue;
        }
        let Ok(ck) = Checkpoint::load(&p) else {
            continue; // unreadable/foreign file: skip, don't fail the scan
        };
        let better = match &best {
            Some((_, b)) => ck.meta.epoch > b.meta.epoch,
            None => true,
        };
        if better {
            best = Some((p, ck));
        }
    }
    best.with_context(|| {
        format!("no loadable *.bin checkpoint in {}", path.display())
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ckpt::format::community_fingerprint;

    fn meta_at(epoch: usize, val_acc: f64) -> CkptMeta {
        CkptMeta {
            dataset: "t".into(),
            model: "host-sgc".into(),
            policy: "host".into(),
            epoch,
            val_acc,
            val_loss: 1.0 - val_acc,
            seed: 7,
            comm_fp: community_fingerprint(&[0, 0, 1], 2),
            num_comms: 2,
            shapes: vec![vec![2, 2]],
            hot_nodes: vec![],
        }
    }

    fn ck_at(epoch: usize, val_acc: f64) -> Checkpoint {
        Checkpoint::new(meta_at(epoch, val_acc), vec![vec![epoch as f32; 4]])
            .unwrap()
    }

    fn tmpdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir()
            .join(format!("comm_rand_store_{tag}_{}", std::process::id()));
        std::fs::remove_dir_all(&d).ok();
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn store_versions_are_monotone_and_snapshots_stable() {
        let st = ParamStore::new();
        assert_eq!(st.version(), 0);
        assert!(st.current().is_none());
        let v1 = st.publish(ck_at(0, 0.5), PathBuf::from("a"));
        assert_eq!(v1.version, 1);
        let held = st.current().unwrap();
        let v2 = st.publish(ck_at(1, 0.6), PathBuf::from("b"));
        assert_eq!(v2.version, 2);
        assert_eq!(st.version(), 2);
        // the old snapshot is untouched by the publish
        assert_eq!(held.version, 1);
        assert_eq!(held.params[0], vec![0.0; 4]);
    }

    #[test]
    fn retention_keeps_best_and_latest_only() {
        let dir = tmpdir("retention");
        let mut w =
            CheckpointWriter::new(&dir, 1, Retention::BestAndLatest).unwrap();
        // val accs: best lands mid-run, then decays
        for (e, acc) in [(0, 0.10), (1, 0.90), (2, 0.30), (3, 0.50)] {
            w.maybe_write(&ck_at(e, acc)).unwrap().expect("every=1 writes");
        }
        let mut on_disk: Vec<String> = std::fs::read_dir(&dir)
            .unwrap()
            .map(|e| e.unwrap().file_name().to_string_lossy().into_owned())
            .collect();
        on_disk.sort();
        assert_eq!(
            on_disk,
            vec!["ckpt-e00001.bin", "ckpt-e00003.bin"],
            "retention must keep best (e1, 0.90) + latest (e3)"
        );
        assert_eq!(w.best().unwrap().epoch, 1);
        assert_eq!(w.latest().unwrap().epoch, 3);
        // when the latest is also the best, a single file remains
        w.write(&ck_at(4, 0.99)).unwrap();
        let files: Vec<_> = std::fs::read_dir(&dir)
            .unwrap()
            .map(|e| e.unwrap().path())
            .collect();
        assert_eq!(files.len(), 1);
        assert!(files[0].ends_with("ckpt-e00004.bin"));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn cadence_respects_every() {
        let dir = tmpdir("cadence");
        let mut w = CheckpointWriter::new(&dir, 2, Retention::All).unwrap();
        let mut written = Vec::new();
        for e in 0..6 {
            if let Some(p) = w.maybe_write(&ck_at(e, 0.5)).unwrap() {
                written.push(p);
            }
        }
        // every=2 fires at epochs 1, 3, 5
        assert_eq!(written.len(), 3);
        assert_eq!(w.entries().len(), 3);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn resolve_picks_highest_epoch_and_skips_garbage() {
        let dir = tmpdir("resolve");
        let mut w = CheckpointWriter::new(&dir, 1, Retention::All).unwrap();
        w.write(&ck_at(2, 0.4)).unwrap();
        w.write(&ck_at(7, 0.3)).unwrap();
        w.write(&ck_at(5, 0.9)).unwrap();
        // garbage that must not derail the scan
        std::fs::write(dir.join("notes.txt"), b"hello").unwrap();
        std::fs::write(dir.join("broken.bin"), b"CRCKgarbage").unwrap();
        let (p, ck) = resolve_checkpoint(&dir).unwrap();
        assert!(p.ends_with("ckpt-e00007.bin"), "{}", p.display());
        assert_eq!(ck.meta.epoch, 7);
        // a file path resolves to itself
        let (p2, ck2) = resolve_checkpoint(&p).unwrap();
        assert_eq!(p2, p);
        assert_eq!(ck2.meta.epoch, 7);
        // an empty dir errors
        let empty = tmpdir("resolve_empty");
        assert!(resolve_checkpoint(&empty).is_err());
        std::fs::remove_dir_all(&dir).ok();
        std::fs::remove_dir_all(&empty).ok();
    }
}
