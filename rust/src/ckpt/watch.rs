//! Checkpoint-directory reload watcher: the serving side of hot
//! swapping.
//!
//! A [`DirWatcher`] polls a checkpoint directory for `*.bin` files it
//! has not seen (or whose mtime changed), decodes and validates each
//! candidate — CRC + community-fingerprint fence, both from
//! [`super::format`] — and surfaces the newest one whose epoch is
//! strictly greater than the last *confirmed install*
//! ([`DirWatcher::mark_loaded`]). Invalid or stale files are
//! remembered and skipped, so a corrupt upload never busy-loops the
//! watcher and never reaches the workers.
//!
//! [`watch_loop`] is the thread body the serving engine runs: poll,
//! hand validated checkpoints to a `publish` callback (the engine
//! publishes to its [`super::ParamStore`] and installs into the
//! executor), sleep, repeat — exiting promptly when `stop` is set.
//! Because checkpoint writers rename atomically, a poll observes
//! either the old file set or the complete new one, never a torn
//! write.
//!
//! Under request tracing the engine's `publish` callback emits a
//! `CkptSwap` instant (carrying the installed epoch) on the dedicated
//! watcher track after each successful install, so hot swaps line up
//! against the per-shard request spans in Perfetto — the watcher
//! itself stays trace-agnostic (see [`crate::obs`]).

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, Ordering};
use std::time::{Duration, SystemTime};

use anyhow::Result;

use super::format::Checkpoint;

/// Incremental scanner over one checkpoint directory (see module docs).
pub struct DirWatcher {
    dir: PathBuf,
    /// Files already examined, by mtime (stale entries are harmless).
    seen: HashMap<PathBuf, SystemTime>,
    /// Epoch of the last checkpoint surfaced (`None` = none yet).
    loaded_epoch: Option<usize>,
}

impl DirWatcher {
    /// Watch `dir`, surfacing only checkpoints newer than
    /// `loaded_epoch` (pass the initially-loaded checkpoint's epoch, or
    /// `None` to surface the first valid file).
    pub fn new(dir: impl Into<PathBuf>, loaded_epoch: Option<usize>) -> DirWatcher {
        DirWatcher {
            dir: dir.into(),
            seen: HashMap::new(),
            loaded_epoch,
        }
    }

    /// The watched directory.
    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// One scan: decode + validate unseen/changed `*.bin` files and
    /// return the newest checkpoint that advances the loaded epoch, if
    /// any. Files that fail to decode or validate are logged once and
    /// not retried until their mtime changes.
    ///
    /// Polling does **not** advance the epoch fence — the caller
    /// confirms a successful install with [`DirWatcher::mark_loaded`].
    /// That way a checkpoint whose install fails (e.g. shapes that
    /// don't fit the executor) doesn't poison the fence: a corrected
    /// re-upload at the same epoch (new mtime) is re-examined and can
    /// still land.
    pub fn poll(
        &mut self,
        community: &[u32],
        num_comms: usize,
    ) -> Option<(PathBuf, Checkpoint)> {
        self.poll_with(&|ck| ck.validate_against(community, num_comms))
    }

    /// Like [`DirWatcher::poll`], but with a caller-supplied validator
    /// — used by streaming serving runs, where a mid-run full relabel
    /// replaces the community labeling (and therefore the fence
    /// fingerprint) the next poll must validate against.
    pub fn poll_with(
        &mut self,
        validate: &dyn Fn(&Checkpoint) -> Result<()>,
    ) -> Option<(PathBuf, Checkpoint)> {
        let entries = match std::fs::read_dir(&self.dir) {
            Ok(e) => e,
            Err(_) => return None, // dir may not exist yet; keep polling
        };
        let mut newest: Option<(usize, PathBuf, Checkpoint)> = None;
        for entry in entries.flatten() {
            let path = entry.path();
            if path.extension().and_then(|e| e.to_str()) != Some("bin") {
                continue;
            }
            let mtime = entry
                .metadata()
                .and_then(|m| m.modified())
                .unwrap_or(SystemTime::UNIX_EPOCH);
            if self.seen.get(&path) == Some(&mtime) {
                continue;
            }
            self.seen.insert(path.clone(), mtime);
            let ck = match Checkpoint::load(&path) {
                Ok(ck) => ck,
                Err(e) => {
                    eprintln!(
                        "[ckpt-watch] ignoring {}: {e:#}",
                        path.display()
                    );
                    continue;
                }
            };
            if let Err(e) = validate(&ck) {
                eprintln!("[ckpt-watch] rejecting {}: {e:#}", path.display());
                continue;
            }
            let advances = match self.loaded_epoch {
                Some(le) => ck.meta.epoch > le,
                None => true,
            };
            let newer_than_candidate = match &newest {
                Some((e, _, _)) => ck.meta.epoch > *e,
                None => true,
            };
            if advances && newer_than_candidate {
                newest = Some((ck.meta.epoch, path, ck));
            }
        }
        newest.map(|(_, path, ck)| (path, ck))
    }

    /// Record that a checkpoint at `epoch` was successfully installed:
    /// only strictly newer epochs surface from now on.
    pub fn mark_loaded(&mut self, epoch: usize) {
        self.loaded_epoch =
            Some(self.loaded_epoch.map_or(epoch, |e| e.max(epoch)));
    }
}

/// Thread body for background hot-swap: poll every `poll_ms`
/// milliseconds, hand each validated new checkpoint to `publish`
/// (which installs it into the serving executor), exit when `stop` is
/// set. `publish` errors are logged, not fatal — the workers keep
/// serving the version they have.
pub fn watch_loop(
    watcher: DirWatcher,
    community: &[u32],
    num_comms: usize,
    poll_ms: u64,
    stop: &AtomicBool,
    publish: &(dyn Fn(PathBuf, Checkpoint) -> Result<()> + Sync),
) {
    watch_loop_with(
        watcher,
        poll_ms,
        stop,
        &|ck| ck.validate_against(community, num_comms),
        publish,
    )
}

/// [`watch_loop`] with a caller-supplied validator, evaluated fresh on
/// every poll — a streaming serving run passes a closure reading its
/// *current* label snapshot, so checkpoints from before a mid-run full
/// relabel stop validating the moment the fence fingerprint changes.
pub fn watch_loop_with(
    watcher: DirWatcher,
    poll_ms: u64,
    stop: &AtomicBool,
    validate: &(dyn Fn(&Checkpoint) -> Result<()> + Sync),
    publish: &(dyn Fn(PathBuf, Checkpoint) -> Result<()> + Sync),
) {
    watch_loop_observed(watcher, poll_ms, stop, validate, publish, &|| {})
}

/// [`watch_loop_with`] plus a liveness `tick` callback, invoked at the
/// top of every poll and during every sleep slice — the serving engine
/// passes a watchdog-heartbeat beat so a watcher wedged inside a
/// decode/validate/publish shows up as a stall while one sleeping
/// between polls stays healthy.
pub fn watch_loop_observed(
    mut watcher: DirWatcher,
    poll_ms: u64,
    stop: &AtomicBool,
    validate: &(dyn Fn(&Checkpoint) -> Result<()> + Sync),
    publish: &(dyn Fn(PathBuf, Checkpoint) -> Result<()> + Sync),
    tick: &(dyn Fn() + Sync),
) {
    let poll_ms = poll_ms.max(1);
    loop {
        if stop.load(Ordering::Relaxed) {
            return;
        }
        tick();
        if let Some((path, ck)) = watcher.poll_with(validate) {
            let label = path.display().to_string();
            let epoch = ck.meta.epoch;
            match publish(path, ck) {
                Ok(()) => {
                    watcher.mark_loaded(epoch);
                    println!("[ckpt-watch] hot-swapped in {label}");
                }
                Err(e) => {
                    // fence NOT advanced: a fixed re-upload of this
                    // epoch (new mtime) can still install later
                    eprintln!("[ckpt-watch] failed to install {label}: {e:#}")
                }
            }
            continue; // re-poll immediately: more files may be pending
        }
        // sleep in short slices so `stop` is honored promptly even at
        // long poll intervals
        let mut left = poll_ms;
        while left > 0 {
            if stop.load(Ordering::Relaxed) {
                return;
            }
            tick();
            let step = left.min(20);
            std::thread::sleep(Duration::from_millis(step));
            left -= step;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ckpt::format::{community_fingerprint, CkptMeta};

    fn community() -> Vec<u32> {
        vec![0, 0, 1, 1, 2, 2]
    }

    fn ck_at(epoch: usize, comm: &[u32]) -> Checkpoint {
        let meta = CkptMeta {
            dataset: "t".into(),
            model: "host-sgc".into(),
            policy: "host".into(),
            epoch,
            val_acc: 0.5,
            val_loss: 0.5,
            seed: 1,
            comm_fp: community_fingerprint(comm, 3),
            num_comms: 3,
            shapes: vec![vec![2]],
            hot_nodes: vec![],
        };
        Checkpoint::new(meta, vec![vec![epoch as f32, 0.0]]).unwrap()
    }

    fn tmpdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir()
            .join(format!("comm_rand_watch_{tag}_{}", std::process::id()));
        std::fs::remove_dir_all(&d).ok();
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn poll_surfaces_only_advancing_epochs() {
        let dir = tmpdir("advance");
        let comm = community();
        let mut w = DirWatcher::new(&dir, Some(2));
        // nothing there yet
        assert!(w.poll(&comm, 3).is_none());
        // an older checkpoint must not surface
        ck_at(1, &comm).write_atomic(&dir.join("ckpt-e00001.bin")).unwrap();
        assert!(w.poll(&comm, 3).is_none());
        // a newer one does, exactly once
        ck_at(5, &comm).write_atomic(&dir.join("ckpt-e00005.bin")).unwrap();
        let (_, ck) = w.poll(&comm, 3).expect("epoch 5 advances past 2");
        assert_eq!(ck.meta.epoch, 5);
        assert!(w.poll(&comm, 3).is_none(), "same file must not re-surface");
        // once the install is confirmed, epochs at/below 5 are fenced
        w.mark_loaded(5);
        ck_at(4, &comm).write_atomic(&dir.join("ckpt-e00004.bin")).unwrap();
        assert!(w.poll(&comm, 3).is_none(), "epoch 4 must not surface");
        ck_at(6, &comm).write_atomic(&dir.join("ckpt-e00006.bin")).unwrap();
        assert_eq!(w.poll(&comm, 3).unwrap().1.meta.epoch, 6);
        std::fs::remove_dir_all(&dir).ok();
    }

    /// A failed install must not poison the fence: the same epoch,
    /// re-uploaded (new mtime), surfaces again because the caller
    /// never confirmed it with `mark_loaded`.
    #[test]
    fn unconfirmed_epoch_can_be_reuploaded_and_resurfaces() {
        let dir = tmpdir("reupload");
        let comm = community();
        let mut w = DirWatcher::new(&dir, Some(1));
        let path = dir.join("ckpt-e00003.bin");
        ck_at(3, &comm).write_atomic(&path).unwrap();
        assert_eq!(w.poll(&comm, 3).unwrap().1.meta.epoch, 3);
        // install failed (no mark_loaded); same mtime → not re-polled
        assert!(w.poll(&comm, 3).is_none());
        // re-upload the fixed checkpoint at the SAME epoch; the sleep
        // guarantees a distinct mtime on any filesystem with >= 10 ms
        // timestamp resolution (ext4/tmpfs are nanosecond)
        std::thread::sleep(Duration::from_millis(20));
        ck_at(3, &comm).write_atomic(&path).unwrap();
        let (_, ck) = w
            .poll(&comm, 3)
            .expect("re-uploaded epoch must surface again");
        assert_eq!(ck.meta.epoch, 3);
        // ...and once confirmed, it is fenced like any installed epoch
        w.mark_loaded(3);
        std::thread::sleep(Duration::from_millis(20));
        ck_at(3, &comm).write_atomic(&path).unwrap();
        assert!(w.poll(&comm, 3).is_none(), "confirmed epoch re-fenced");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn poll_skips_invalid_files_without_stalling() {
        let dir = tmpdir("invalid");
        let comm = community();
        let mut w = DirWatcher::new(&dir, None);
        // corrupt file + fingerprint-mismatched file + valid file
        std::fs::write(dir.join("junk.bin"), b"CRCKnope").unwrap();
        let foreign = vec![0u32, 1, 2, 0, 1, 2];
        ck_at(9, &foreign).write_atomic(&dir.join("ckpt-e00009.bin")).unwrap();
        ck_at(4, &comm).write_atomic(&dir.join("ckpt-e00004.bin")).unwrap();
        let (_, ck) = w.poll(&comm, 3).expect("the valid file surfaces");
        assert_eq!(ck.meta.epoch, 4);
        // the bad files stay ignored on later polls
        assert!(w.poll(&comm, 3).is_none());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn poll_picks_newest_when_several_land_at_once() {
        let dir = tmpdir("newest");
        let comm = community();
        let mut w = DirWatcher::new(&dir, None);
        for e in [3usize, 8, 6] {
            ck_at(e, &comm)
                .write_atomic(&dir.join(format!("ckpt-e{e:05}.bin")))
                .unwrap();
        }
        let (_, ck) = w.poll(&comm, 3).unwrap();
        assert_eq!(ck.meta.epoch, 8, "newest epoch wins");
        // the older two never surface later: already examined (seen
        // by mtime), so only a rewrite would re-candidate them
        assert!(w.poll(&comm, 3).is_none());
        std::fs::remove_dir_all(&dir).ok();
    }
}
