//! PJRT API shim with the exact surface `comm_rand::runtime` consumes.
//!
//! The offline build image has neither the `xla` registry crate nor a
//! native XLA/PJRT library, so this shim keeps the whole workspace
//! compiling and lets every non-executing code path (manifest parsing,
//! dataset pipeline, sampling, batch assembly, cache models, the
//! serving engine's no-op executor) run for real. Anything that would
//! actually execute an HLO module returns a clear
//! "PJRT execution unavailable" error instead; swap this path
//! dependency for a real xla-rs build with the same API to run the AOT
//! artifacts.

use std::borrow::Borrow;
use std::path::Path;

/// Error type; call sites only format it with `{:?}`.
#[derive(Debug, Clone)]
pub struct Error(pub String);

pub type Result<T> = std::result::Result<T, Error>;

const UNAVAILABLE: &str =
    "PJRT execution unavailable: built against the offline xla shim \
     (rust/vendor/xla); link a real xla-rs to run AOT artifacts";

/// Element types uploadable as device buffers.
pub trait ArrayElement: Copy + Send + Sync + 'static {
    const NAME: &'static str;
}

impl ArrayElement for f32 {
    const NAME: &'static str = "f32";
}

impl ArrayElement for i32 {
    const NAME: &'static str = "i32";
}

/// Placeholder device handle (the `Option<&PjRtDevice>` parameter of
/// `buffer_from_host_buffer`).
#[derive(Debug, Clone, Copy)]
pub struct PjRtDevice;

#[derive(Clone)]
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Ok(PjRtClient)
    }

    pub fn platform_name(&self) -> String {
        "cpu (offline xla shim)".to_string()
    }

    pub fn device_count(&self) -> usize {
        1
    }

    pub fn buffer_from_host_buffer<T: ArrayElement>(
        &self,
        data: &[T],
        dims: &[usize],
        _device: Option<&PjRtDevice>,
    ) -> Result<PjRtBuffer> {
        let want: usize = dims.iter().product();
        // scalars are passed with empty dims
        if !dims.is_empty() && want != data.len() {
            return Err(Error(format!(
                "host buffer has {} elements, shape {:?} wants {}",
                data.len(),
                dims,
                want
            )));
        }
        Ok(PjRtBuffer { elements: data.len() })
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error(UNAVAILABLE.to_string()))
    }
}

/// Device buffer handle (host-side bookkeeping only in the shim).
pub struct PjRtBuffer {
    pub elements: usize,
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error(UNAVAILABLE.to_string()))
    }
}

pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute_b<L: Borrow<PjRtBuffer>>(
        &self,
        _args: &[L],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error(UNAVAILABLE.to_string()))
    }
}

/// Parsed HLO module (the shim only checks the file is readable).
pub struct HloModuleProto {
    pub text: String,
}

impl HloModuleProto {
    pub fn from_text_file(path: &Path) -> Result<HloModuleProto> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| Error(format!("{}: {e}", path.display())))?;
        Ok(HloModuleProto { text })
    }
}

pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// Host literal (never constructed by the shim; kept for API parity).
pub struct Literal;

impl Literal {
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        Err(Error(UNAVAILABLE.to_string()))
    }

    pub fn to_vec<T: ArrayElement>(&self) -> Result<Vec<T>> {
        Err(Error(UNAVAILABLE.to_string()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_and_buffers_work_without_pjrt() {
        let c = PjRtClient::cpu().unwrap();
        assert_eq!(c.device_count(), 1);
        let b = c.buffer_from_host_buffer(&[1.0f32; 6], &[2, 3], None).unwrap();
        assert_eq!(b.elements, 6);
        assert!(c.buffer_from_host_buffer(&[1.0f32; 5], &[2, 3], None).is_err());
        // scalar upload with empty dims
        assert!(c.buffer_from_host_buffer(&[1i32], &[], None).is_ok());
    }

    #[test]
    fn execution_reports_unavailable() {
        let c = PjRtClient::cpu().unwrap();
        let err = c.compile(&XlaComputation).unwrap_err();
        assert!(err.0.contains("unavailable"));
    }
}
