//! Offline drop-in subset of the `anyhow` crate.
//!
//! The image this repository builds in has no registry access, so the
//! error-handling surface the codebase uses is re-implemented here:
//! [`Error`], [`Result`], [`anyhow!`], [`bail!`] and the [`Context`]
//! extension trait for `Result` and `Option`. An [`Error`] is a chain
//! of display strings (outermost context first); `{:#}` formats the
//! whole chain on one line and `{:?}` formats it anyhow-style with a
//! `Caused by:` block.

use std::fmt;

/// A chain of error messages; `chain[0]` is the outermost context.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Construct from any displayable message (what `anyhow!` expands to).
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { chain: vec![message.to_string()] }
    }

    /// Wrap with an outer context message.
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The outermost message.
    pub fn to_msg(&self) -> &str {
        &self.chain[0]
    }

    /// The message chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain[0])
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain[0])?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for (i, c) in self.chain[1..].iter().enumerate() {
                write!(f, "\n    {i}: {c}")?;
            }
        }
        Ok(())
    }
}

// `Error` deliberately does NOT implement `std::error::Error`: that is
// what lets this blanket conversion coexist with the reflexive
// `From<T> for T` impl, exactly as in the real anyhow.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Construct an [`Error`] from a format string or displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(::std::format!($msg))
    };
    ($fmt:expr, $($arg:tt)+) => {
        $crate::Error::msg(::std::format!($fmt, $($arg)+))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Early-return with an [`Error`].
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($t)*))
    };
}

/// Attach context to errors (`Result`) or missing values (`Option`).
pub trait Context<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(
        self,
        context: C,
    ) -> Result<T>;

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E: Into<Error>> Context<T> for Result<T, E> {
    fn context<C: fmt::Display + Send + Sync + 'static>(
        self,
        context: C,
    ) -> Result<T> {
        self.map_err(|e| {
            let err: Error = e.into();
            err.context(context)
        })
    }

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| {
            let err: Error = e.into();
            err.context(f())
        })
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(
        self,
        context: C,
    ) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "gone")
    }

    #[test]
    fn macro_forms() {
        let a: Error = anyhow!("plain");
        assert_eq!(a.to_msg(), "plain");
        let b: Error = anyhow!("x = {}", 7);
        assert_eq!(b.to_msg(), "x = 7");
        let c: Error = anyhow!(String::from("owned"));
        assert_eq!(c.to_msg(), "owned");
    }

    #[test]
    fn bail_returns() {
        fn f(fail: bool) -> Result<u32> {
            if fail {
                bail!("no {}", "good");
            }
            Ok(3)
        }
        assert_eq!(f(false).unwrap(), 3);
        assert_eq!(f(true).unwrap_err().to_msg(), "no good");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn f() -> Result<String> {
            let s = std::str::from_utf8(&[0xffu8])?;
            Ok(s.to_string())
        }
        assert!(f().is_err());
    }

    #[test]
    fn context_chains() {
        let r: Result<()> = Err(io_err().into());
        let e = r.context("opening config").unwrap_err();
        assert_eq!(e.to_msg(), "opening config");
        assert_eq!(format!("{e:#}"), "opening config: gone");
        assert!(format!("{e:?}").contains("Caused by:"));
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.with_context(|| format!("missing {}", "key")).unwrap_err();
        assert_eq!(e.to_msg(), "missing key");
        assert_eq!(Some(5u32).context("x").unwrap(), 5);
    }
}
