//! `cargo bench figures` — quick-mode regeneration of every paper
//! table/figure (full-budget versions run via `comm-rand exp <id>`).
//! Each experiment writes its artifact into `results/` and prints the
//! headline rows.

fn main() -> anyhow::Result<()> {
    std::env::set_var("COMM_RAND_FAST", "1");
    let ids = [
        "fig2", "fig5", "fig6", "fig7", "fig8", "fig9", "fig10",
        "tab4", "tab5", "fullbatch", "inference", "preproc",
    ];
    for id in ids {
        println!("\n================ exp {id} (quick) ================");
        let args = comm_rand::cli_args(vec!["exp".into(), id.into()]);
        if let Err(e) = comm_rand::exp::run(&args) {
            println!("exp {id} failed: {e:#}");
        }
    }
    Ok(())
}
