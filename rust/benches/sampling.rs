//! Sampling-path micro benchmarks: root partitioning policies, biased
//! neighbor sampling, MFG construction and batch assembly throughput
//! (no external criterion offline — util::bench is the harness).

use comm_rand::batch::assemble;
use comm_rand::config::preset;
use comm_rand::runtime::artifact::{default_dir, Manifest};
use comm_rand::sampler::roots::order_roots;
use comm_rand::sampler::{build_mfg, NeighborPolicy, RootPolicy};
use comm_rand::train::dataset::load_or_build;
use comm_rand::util::bench::bench;
use comm_rand::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let p = preset("reddit_sim").unwrap();
    let ds = load_or_build(&p, true)?;
    let train = ds.train_nodes();
    println!("== sampling micro-benchmarks (reddit_sim) ==");

    let mut rng = Rng::new(1);
    for policy in [
        RootPolicy::Rand,
        RootPolicy::NoRand,
        RootPolicy::CommRandMix { pct: 0.125 },
    ] {
        bench(&format!("order_roots/{}", policy.label()), 0.4, || {
            order_roots(policy, &train, &ds.community, &mut rng)
        });
    }

    let roots: Vec<u32> = train[..256].to_vec();
    for (label, pol) in [
        ("uniform", NeighborPolicy::Uniform),
        ("biased_p0.9", NeighborPolicy::Biased { p: 0.9 }),
        ("biased_p1.0", NeighborPolicy::Biased { p: 1.0 }),
    ] {
        bench(&format!("build_mfg/5-10-10/{label}"), 0.6, || {
            build_mfg(&ds.csr, &ds.community, &roots, &[5, 10, 10], pol, &mut rng)
        });
    }

    if let Ok(manifest) = Manifest::load(&default_dir()) {
        let meta = manifest.get("reddit_sim.train")?;
        let mfg = build_mfg(
            &ds.csr, &ds.community, &roots, &[5, 10, 10],
            NeighborPolicy::Uniform, &mut rng,
        );
        bench("assemble/reddit_sim", 0.6, || {
            assemble(&mfg, &ds, meta, true).unwrap()
        });
    } else {
        println!("(artifacts missing — skipping assemble bench)");
    }
    bench_maps();
    Ok(())
}

// appended: U32Map vs std::HashMap on the MFG dedup workload (the
// §Perf A/B for the sampling hot path)
pub fn bench_maps() {
    use comm_rand::util::umap::U32Map;
    use std::collections::HashMap;
    let mut rng = Rng::new(7);
    let keys: Vec<u32> = (0..30_000).map(|_| rng.below(16384) as u32).collect();
    bench("dedup_map/std_hashmap", 0.5, || {
        let mut m: HashMap<u32, u32> = HashMap::with_capacity(8192);
        let mut n = 0u32;
        for &k in &keys {
            let v = *m.entry(k).or_insert_with(|| { n += 1; n });
            std::hint::black_box(v);
        }
        m.len()
    });
    bench("dedup_map/u32map", 0.5, || {
        let mut m = U32Map::with_capacity(8192);
        let mut n = 0u32;
        for &k in &keys {
            let v = m.get_or_insert_with(k, || { n += 1; n });
            std::hint::black_box(v);
        }
        m.len()
    });
}
