//! End-to-end per-epoch benchmark: full trainer epochs under the
//! baseline and the paper's best COMM-RAND knobs (the quantity behind
//! every per-epoch speedup row in the paper). Wall-clock and the
//! modelled device time are both reported.

use comm_rand::config::{preset, BatchPolicy, TrainConfig};
use comm_rand::sampler::RootPolicy;
use comm_rand::train::{self, Method, RunOptions, Session};

fn main() -> anyhow::Result<()> {
    let name = std::env::args()
        .skip(1)
        .find(|a| !a.starts_with("--"))
        .unwrap_or_else(|| "reddit_sim".into());
    let p = preset(&name).expect("unknown preset");
    let ds = train::dataset::load_or_build(&p, true)?;
    let mut session = Session::new()?;
    let cfg = TrainConfig { max_epochs: 3, ..Default::default() };
    let opts = RunOptions::default();

    println!("== per-epoch benchmark ({name}) ==");
    let mut base_wall = 0.0;
    let mut base_model = 0.0;
    for (label, pol) in [
        ("RAND-ROOTS+p0.5 (baseline)", BatchPolicy::baseline()),
        (
            "NORAND-ROOTS+p1.0",
            BatchPolicy { roots: RootPolicy::NoRand, p_intra: 1.0 },
        ),
        (
            "COMM-RAND-MIX-12.5%+p1.0",
            BatchPolicy {
                roots: RootPolicy::CommRandMix { pct: 0.125 },
                p_intra: 1.0,
            },
        ),
    ] {
        let r = train::train(
            &mut session,
            &ds,
            p.artifact,
            &Method::CommRand(pol),
            &cfg,
            &opts,
        )?;
        let wall = r.mean_epoch_wall_s();
        let model = r.mean_epoch_modeled_s();
        if base_wall == 0.0 {
            base_wall = wall;
            base_model = model;
        }
        println!(
            "{label:<28} wall {wall:.3}s ({:.2}x)   modeled {model:.4}s ({:.2}x)",
            base_wall / wall,
            base_model / model
        );
    }
    Ok(())
}
