"""Layer-1 Pallas kernel: weighted gather-aggregate (the GNN hot-spot).

``gather_wsum(src, idx, w) -> out`` computes, for every output row ``i``::

    out[i, :] = sum_k  w[i, k] * src[idx[i, k], :]

This one primitive implements every neighborhood aggregation the models
need:

* **GraphSAGE mean aggregation** — ``w[i, k] = mask[i, k] / deg(i)``
* **GCN symmetric-normalized sum** — ``w[i, k] = mask / sqrt(deg_i deg_k)``
* **masked self-gather** — ``K = 1``, ``w = 1``

Hardware adaptation (see DESIGN.md §Hardware-Adaptation): the paper's
GPU framing is "random neighbor gathers thrash the L2"; on TPU the same
insight becomes a VMEM blocking question.  The kernel keeps the full
``src`` feature table in HBM-resident memory, streams output-row blocks
(``block_rows`` at a time) through VMEM, and performs the K-way gather +
multiply-accumulate per block, so the VMEM working set is
``block_rows * (K + F + K*F)`` words regardless of graph size.  Pallas is
run with ``interpret=True`` (the CPU PJRT plugin cannot execute Mosaic
custom-calls), which lowers the same schedule to plain HLO.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref as _ref


def _gather_wsum_kernel(src_ref, idx_ref, w_ref, out_ref, *, fanout: int):
    """One output-row block: out = sum_k w[:, k] * src[idx[:, k], :]."""
    acc = jnp.zeros(out_ref.shape, jnp.float32)
    # K is small and static (the sampler fanout); unrolling keeps each
    # step a row-gather + FMA, which the interpreter lowers to
    # dynamic-gather + multiply-add HLO.
    for k in range(fanout):
        rows = idx_ref[:, k]
        g = src_ref[rows, :]
        acc = acc + w_ref[:, k][:, None] * g
    out_ref[...] = acc


def _gather_wsum_pallas(src, idx, w, *, block_rows: int = 128):
    """Weighted gather-sum aggregation (pallas forward).

    Args:
      src: ``[n_in, feat]`` float32 feature table.
      idx: ``[n_out, fanout]`` int32 row indices into ``src``. Padded
        entries must point at a valid row (canonically 0) and carry
        ``w == 0``.
      w:   ``[n_out, fanout]`` float32 per-edge weights (mask folded in).
      block_rows: rows of the output computed per grid step. ``n_out``
        must be a multiple of ``block_rows``.

    Returns:
      ``[n_out, feat]`` float32 aggregated features.
    """
    n_in, feat = src.shape
    n_out, fanout = idx.shape
    assert w.shape == (n_out, fanout), (w.shape, idx.shape)
    assert n_out % block_rows == 0, (n_out, block_rows)
    grid = (n_out // block_rows,)
    kernel = functools.partial(_gather_wsum_kernel, fanout=fanout)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            # Whole feature table visible to every block (HBM-resident on
            # real hardware; the gather pulls only the referenced rows).
            pl.BlockSpec((n_in, feat), lambda i: (0, 0)),
            pl.BlockSpec((block_rows, fanout), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, fanout), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_rows, feat), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_out, feat), jnp.float32),
        interpret=True,
    )(src, idx, w)


# ``pallas_call`` defines no autodiff rule, so the backward pass is the
# VJP of the mathematically-identical pure-jnp oracle (kernels/ref.py).
# d_src is an XLA scatter-add, d_w a gather-dot; the cotangent of a
# non-differentiated src (e.g. the resident feature table at layer 1) is
# dead code and pruned by XLA.
@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def _gather_wsum_cv(src, idx, w, block_rows):
    return _gather_wsum_pallas(src, idx, w, block_rows=block_rows)


def _gather_wsum_fwd(src, idx, w, block_rows):
    return _gather_wsum_pallas(src, idx, w, block_rows=block_rows), (src, idx, w)


def _gather_wsum_bwd(block_rows, res, g):
    src, idx, w = res
    _, vjp = jax.vjp(_ref.gather_wsum_ref, src, idx, w)
    d_src, _, d_w = vjp(g)
    return d_src, None, d_w


_gather_wsum_cv.defvjp(_gather_wsum_fwd, _gather_wsum_bwd)


def gather_wsum(src, idx, w, *, block_rows: int = 128):
    """Differentiable weighted gather-sum: see ``_gather_wsum_pallas``."""
    return _gather_wsum_cv(src, idx, w, block_rows)


def gather_rows(src, idx, *, block_rows: int = 128):
    """Plain row gather ``out[i] = src[idx[i]]`` as a K=1 gather_wsum."""
    n_out = idx.shape[0]
    ones = jnp.ones((n_out, 1), jnp.float32)
    return gather_wsum(src, idx[:, None], ones, block_rows=block_rows)
