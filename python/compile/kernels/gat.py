"""Layer-1 Pallas kernel: fused GAT edge-attention aggregation.

For each destination row ``i`` with candidate neighbors ``idx[i, :K]``
(mask ``m``), multi-head attention over the sampled neighborhood::

    e[i, k, h]   = LeakyReLU(s_dst[i, h] + s_src[idx[i, k], h])
    alpha[i,:,h] = softmax_k(e[i, :, h])   (masked)
    out[i, h, :] = sum_k alpha[i, k, h] * wh[idx[i, k], h, :]

``wh`` is the already-projected feature table ``W x`` with heads folded
into the trailing dim (``[n_in, heads*dh]``); ``s_src``/``s_dst`` are the
per-node attention logits ``(W x) . a_src`` / ``(W x) . a_dst`` computed
by dense matmuls in Layer 2 (MXU-friendly), so the kernel only does the
irregular part: gather, masked softmax, weighted sum.  This mirrors how
the paper's GPU story maps to TPU: the regular dense work targets the
MXU, the neighbor-dependent work is blocked through VMEM.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref as _ref

_NEG_BIG = -1e9


def _gat_kernel(wh_ref, ssrc_ref, sdst_ref, idx_ref, mask_ref, out_ref, *,
                fanout: int, heads: int, dh: int, slope: float):
    bn = out_ref.shape[0]
    sdst = sdst_ref[...]  # [bn, H]
    # Gather neighbor logits and projected features.
    e = jnp.zeros((bn, fanout, heads), jnp.float32)
    g = jnp.zeros((bn, fanout, heads * dh), jnp.float32)
    for k in range(fanout):
        rows = idx_ref[:, k]
        e = e.at[:, k, :].set(ssrc_ref[rows, :])
        g = g.at[:, k, :].set(wh_ref[rows, :])
    e = e + sdst[:, None, :]
    e = jnp.where(e > 0, e, slope * e)  # LeakyReLU
    mask = mask_ref[...]  # [bn, K]
    e = jnp.where(mask[:, :, None] > 0, e, _NEG_BIG)
    e = e - jnp.max(e, axis=1, keepdims=True)
    ex = jnp.exp(e) * mask[:, :, None]
    denom = jnp.maximum(jnp.sum(ex, axis=1, keepdims=True), 1e-9)
    alpha = ex / denom  # [bn, K, H]
    gh = g.reshape(bn, fanout, heads, dh)
    out = jnp.einsum("bkh,bkhd->bhd", alpha, gh)
    out_ref[...] = out.reshape(bn, heads * dh)


def _gat_aggregate_pallas(wh, s_src, s_dst, idx, mask, *, heads: int,
                          block_rows: int = 128, slope: float = 0.2):
    """Fused masked-softmax attention aggregation.

    Args:
      wh:    ``[n_in, heads*dh]`` projected features.
      s_src: ``[n_in, heads]`` source attention logits.
      s_dst: ``[n_out, heads]`` destination attention logits.
      idx:   ``[n_out, fanout]`` int32 neighbor indices into ``wh``.
      mask:  ``[n_out, fanout]`` float32 validity mask (1 = real edge).
      heads: number of attention heads.

    Returns:
      ``[n_out, heads*dh]`` aggregated features.
    """
    n_in, hd = wh.shape
    n_out, fanout = idx.shape
    assert hd % heads == 0
    dh = hd // heads
    assert n_out % block_rows == 0, (n_out, block_rows)
    grid = (n_out // block_rows,)
    kernel = functools.partial(
        _gat_kernel, fanout=fanout, heads=heads, dh=dh, slope=slope)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((n_in, hd), lambda i: (0, 0)),
            pl.BlockSpec((n_in, heads), lambda i: (0, 0)),
            pl.BlockSpec((block_rows, heads), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, fanout), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, fanout), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_rows, hd), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_out, hd), jnp.float32),
        interpret=True,
    )(wh, s_src, s_dst, idx, mask)


# Backward = VJP of the pure-jnp oracle (pallas_call has no autodiff
# rule); see kernels/gather.py for the rationale.
@functools.partial(jax.custom_vjp, nondiff_argnums=(5, 6, 7))
def _gat_cv(wh, s_src, s_dst, idx, mask, heads, block_rows, slope):
    return _gat_aggregate_pallas(wh, s_src, s_dst, idx, mask, heads=heads,
                                 block_rows=block_rows, slope=slope)


def _gat_fwd(wh, s_src, s_dst, idx, mask, heads, block_rows, slope):
    out = _gat_aggregate_pallas(wh, s_src, s_dst, idx, mask, heads=heads,
                                block_rows=block_rows, slope=slope)
    return out, (wh, s_src, s_dst, idx, mask)


def _gat_bwd(heads, block_rows, slope, res, g):
    wh, s_src, s_dst, idx, mask = res
    fn = functools.partial(_ref.gat_aggregate_ref, heads=heads, slope=slope)
    _, vjp = jax.vjp(lambda a, b, c: fn(a, b, c, idx, mask), wh, s_src, s_dst)
    d_wh, d_ssrc, d_sdst = vjp(g)
    return d_wh, d_ssrc, d_sdst, None, None


_gat_cv.defvjp(_gat_fwd, _gat_bwd)


def gat_aggregate(wh, s_src, s_dst, idx, mask, *, heads: int,
                  block_rows: int = 128, slope: float = 0.2):
    """Differentiable fused GAT aggregation: see ``_gat_aggregate_pallas``."""
    return _gat_cv(wh, s_src, s_dst, idx, mask, heads, block_rows, slope)
