"""Pure-jnp oracles for the Pallas kernels (the correctness contract).

Every kernel in this package must match its oracle to float32 tolerance
across the shape/dtype sweep in ``python/tests/test_kernels.py``.
"""

from __future__ import annotations

import jax.numpy as jnp


def gather_wsum_ref(src, idx, w):
    """out[i] = sum_k w[i, k] * src[idx[i, k]]."""
    g = src[idx]  # [n_out, K, F]
    return jnp.einsum("ok,okf->of", w, g)


def gather_rows_ref(src, idx):
    return src[idx]


def gat_aggregate_ref(wh, s_src, s_dst, idx, mask, *, heads, slope=0.2):
    n_out, fanout = idx.shape
    hd = wh.shape[1]
    dh = hd // heads
    e = s_dst[:, None, :] + s_src[idx]  # [n_out, K, H]
    e = jnp.where(e > 0, e, slope * e)
    e = jnp.where(mask[:, :, None] > 0, e, -1e9)
    e = e - jnp.max(e, axis=1, keepdims=True)
    ex = jnp.exp(e) * mask[:, :, None]
    denom = jnp.maximum(jnp.sum(ex, axis=1, keepdims=True), 1e-9)
    alpha = ex / denom
    gh = wh[idx].reshape(n_out, fanout, heads, dh)
    out = jnp.einsum("bkh,bkhd->bhd", alpha, gh)
    return out.reshape(n_out, hd)
