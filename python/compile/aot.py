"""AOT lowering: JAX entry points -> HLO text + manifest.json.

Interchange format is HLO **text**, not a serialized ``HloModuleProto``:
jax >= 0.5 emits protos with 64-bit instruction ids that the runtime's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the HLO text
parser reassigns ids and round-trips cleanly (see
/opt/xla-example/README.md).

Python runs exactly once, at build time (`make artifacts`); the rust
coordinator loads the emitted text through PJRT and never imports
python on the training path.

Usage::

    cd python && python -m compile.aot --out ../artifacts [--only tiny]
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M
from .specs import FULLBATCH_SPECS, MINI_SPECS, FullBatchSpec, ModelSpec


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text()


def _abstract(shape, dtype):
    jdt = {"f32": jnp.float32, "i32": jnp.int32}[dtype]
    return jax.ShapeDtypeStruct(shape, jdt)


def _io_entry(name, shape, dtype):
    return {"name": name, "shape": list(shape), "dtype": dtype}


def _mini_signature(spec: ModelSpec, kind: str):
    """Flattened (inputs, outputs) manifest entries for a mini-batch
    artifact. ``kind`` is "train" or "infer"."""
    pshapes = M.param_shapes(spec)
    ins, outs = [], []
    for n, s in pshapes:
        ins.append(_io_entry(f"p.{n}", s, "f32"))
    if kind == "train":
        for n, s in pshapes:
            ins.append(_io_entry(f"m.{n}", s, "f32"))
        for n, s in pshapes:
            ins.append(_io_entry(f"v.{n}", s, "f32"))
        ins.append(_io_entry("t", (), "f32"))
        ins.append(_io_entry("lr", (), "f32"))
    for n, s, d in M.batch_inputs(spec, with_labels=(kind == "train")):
        ins.append(_io_entry(n, s, d))
    if kind == "train":
        for n, s in pshapes:
            outs.append(_io_entry(f"p.{n}", s, "f32"))
        for n, s in pshapes:
            outs.append(_io_entry(f"m.{n}", s, "f32"))
        for n, s in pshapes:
            outs.append(_io_entry(f"v.{n}", s, "f32"))
        outs.append(_io_entry("loss", (), "f32"))
        outs.append(_io_entry("correct", (), "f32"))
    else:
        outs.append(_io_entry(
            "logits", (spec.node_caps[spec.layers], spec.num_classes), "f32"))
    return ins, outs


def _fullbatch_signature(spec: FullBatchSpec, kind: str):
    pshapes = M.fullbatch_param_shapes(spec)
    n, e = spec.num_nodes, spec.padded_edges
    ins, outs = [], []
    for nm, s in pshapes:
        ins.append(_io_entry(f"p.{nm}", s, "f32"))
    if kind == "train":
        for nm, s in pshapes:
            ins.append(_io_entry(f"m.{nm}", s, "f32"))
        for nm, s in pshapes:
            ins.append(_io_entry(f"v.{nm}", s, "f32"))
        ins.append(_io_entry("t", (), "f32"))
        ins.append(_io_entry("lr", (), "f32"))
    ins.append(_io_entry("x", (n, spec.feat_dim), "f32"))
    ins.append(_io_entry("e_src", (e,), "i32"))
    ins.append(_io_entry("e_dst", (e,), "i32"))
    ins.append(_io_entry("e_w", (e,), "f32"))
    if kind == "train":
        ins.append(_io_entry("labels", (n,), "i32"))
        ins.append(_io_entry("train_mask", (n,), "f32"))
        ins.append(_io_entry("val_mask", (n,), "f32"))
        for nm, s in pshapes:
            outs.append(_io_entry(f"p.{nm}", s, "f32"))
        for nm, s in pshapes:
            outs.append(_io_entry(f"m.{nm}", s, "f32"))
        for nm, s in pshapes:
            outs.append(_io_entry(f"v.{nm}", s, "f32"))
        outs.append(_io_entry("loss", (), "f32"))
        outs.append(_io_entry("correct_train", (), "f32"))
        outs.append(_io_entry("correct_val", (), "f32"))
    else:
        outs.append(_io_entry("logits", (n, spec.num_classes), "f32"))
    return ins, outs


def lower_artifact(fn, inputs) -> str:
    """jit + lower a python step function against abstract inputs."""
    abstracts = [_abstract(tuple(i["shape"]), i["dtype"]) for i in inputs]
    lowered = jax.jit(fn).lower(*abstracts)
    return to_hlo_text(lowered)


def build_all(out_dir: str, only: str | None = None, verbose: bool = True):
    os.makedirs(out_dir, exist_ok=True)
    manifest = {"format": 1, "artifacts": {}}

    jobs = []
    for spec in MINI_SPECS:
        if only and spec.name != only:
            continue
        jobs.append(("train", spec))
        jobs.append(("infer", spec))
    for spec in FULLBATCH_SPECS:
        if only and spec.name != only:
            continue
        jobs.append(("fb_train", spec))
        jobs.append(("fb_infer", spec))

    for kind, spec in jobs:
        if kind == "train":
            fn = M.make_train_step(spec)
            ins, outs = _mini_signature(spec, "train")
            name = f"{spec.name}.train"
        elif kind == "infer":
            fn = M.make_infer_step(spec)
            ins, outs = _mini_signature(spec, "infer")
            name = f"{spec.name}.infer"
        elif kind == "fb_train":
            fn = M.make_fullbatch_train_step(spec)
            ins, outs = _fullbatch_signature(spec, "train")
            name = f"{spec.name}.train"
        else:
            fn = M.make_fullbatch_infer_step(spec)
            ins, outs = _fullbatch_signature(spec, "infer")
            name = f"{spec.name}.infer"

        if verbose:
            print(f"[aot] lowering {name} ({len(ins)} inputs)...",
                  flush=True)
        hlo = lower_artifact(fn, ins)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(hlo)
        entry = {
            "file": fname,
            "kind": kind,
            "spec": spec.to_json(),
            "inputs": ins,
            "outputs": outs,
            "sha256": hashlib.sha256(hlo.encode()).hexdigest(),
        }
        manifest["artifacts"][name] = entry
        if verbose:
            print(f"[aot]   -> {fname}: {len(hlo)} chars", flush=True)

    # Merge with an existing manifest when building a subset.
    mpath = os.path.join(out_dir, "manifest.json")
    if only and os.path.exists(mpath):
        with open(mpath) as f:
            old = json.load(f)
        old["artifacts"].update(manifest["artifacts"])
        manifest = old
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=1, sort_keys=True)
    if verbose:
        print(f"[aot] manifest: {mpath} "
              f"({len(manifest['artifacts'])} artifacts)")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--only", default=None,
                    help="build a single spec by name")
    args = ap.parse_args()
    build_all(args.out, args.only)


if __name__ == "__main__":
    main()
