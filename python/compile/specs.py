"""Artifact specifications — the single source of truth for model shapes.

Every AOT artifact (train step / infer step / full-batch step) is
described by a spec here.  ``aot.py`` lowers each spec to HLO text and
records the exact flattened input/output signature in
``artifacts/manifest.json``; the rust runtime wires buffers by that
manifest and never guesses shapes.

The dataset dimensions mirror the *simulated* stand-ins for the paper's
four benchmarks (see DESIGN.md §Datasets): the real reddit /
ogbn-products / igb-small / ogbn-papers100M graphs do not fit a CPU-only
testbed, so we generate SBM-style community graphs with matched label
counts, feature dims, and train splits at reduced node scale.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass

BLOCK_ROWS = 128  # pallas output-row block; all caps are multiples of this


def _round_up(x: int, m: int = BLOCK_ROWS) -> int:
    return ((x + m - 1) // m) * m


@dataclass(frozen=True)
class ModelSpec:
    """One mini-batch GNN training/inference artifact."""

    name: str                 # artifact base name
    model: str                # "sage" | "gcn" | "gat"
    num_nodes: int            # |V| of the target graph (for resident X)
    feat_dim: int
    hidden_dim: int
    num_classes: int
    # Per-layer fanouts, INPUT-most first (DGL convention reversed:
    # fanouts[0] expands the largest frontier, so it is the cheapest).
    fanouts: tuple = (5, 10, 10)
    batch_size: int = 256
    heads: int = 1            # GAT only
    feat_mode: str = "resident"  # "resident" | "staged"
    weight_decay: float = 5e-4

    @property
    def layers(self) -> int:
        return len(self.fanouts)

    def idx_width(self, layer: int) -> int:
        """Neighbor slots per dst row of 1-based `layer`. GCN/GAT carry
        the self-loop in slot 0; SAGE keeps a separate self gather."""
        return self.fanouts[layer - 1] + (
            1 if self.model in ("gcn", "gat") else 0)

    @property
    def node_caps(self) -> list[int]:
        """Padded unique-node capacity per level, input-most first.

        ``caps[l]`` bounds the dst rows of layer ``l`` (1-based); index 0
        is the input frontier capacity (only materialized in staged
        mode).  Worst case without dedup is the running product of
        ``fanout_l + 1``, clamped to |V|.
        """
        caps = [self.batch_size]
        for f in reversed(self.fanouts):
            caps.append(min(caps[-1] * (f + 1), self.num_nodes))
        caps = [_round_up(c) for c in reversed(caps)]  # input-most first
        return caps

    @property
    def dims(self) -> list[int]:
        """Per-layer io dims: [feat, hidden, ..., classes]."""
        d = [self.feat_dim]
        for _ in range(self.layers - 1):
            d.append(self.hidden_dim)
        d.append(self.num_classes)
        return d

    def to_json(self) -> dict:
        d = dataclasses.asdict(self)
        d["fanouts"] = list(self.fanouts)
        d["layers"] = self.layers
        d["node_caps"] = self.node_caps
        d["idx_widths"] = [self.idx_width(l) for l in range(1, self.layers + 1)]
        d["block_rows"] = BLOCK_ROWS
        return d


@dataclass(frozen=True)
class FullBatchSpec:
    """Full-graph GCN training artifact (baseline for §2's mini-batch
    vs full-batch comparison and the §3 inference-reordering study)."""

    name: str
    num_nodes: int            # padded |V|
    num_edges: int            # padded directed edge slots (incl. self loops)
    feat_dim: int
    hidden_dim: int
    num_classes: int
    layers: int = 3
    edge_chunk: int = 65536   # lax.scan chunk for segment-sum propagation
    weight_decay: float = 5e-4

    @property
    def padded_edges(self) -> int:
        return _round_up(self.num_edges, self.edge_chunk)

    @property
    def dims(self) -> list[int]:
        d = [self.feat_dim]
        for _ in range(self.layers - 1):
            d.append(self.hidden_dim)
        d.append(self.num_classes)
        return d

    def to_json(self) -> dict:
        d = dataclasses.asdict(self)
        d["padded_edges"] = self.padded_edges
        return d


# ---------------------------------------------------------------------------
# Default artifact set.  Dataset stand-ins (DESIGN.md §Datasets):
#   reddit_sim    : 16384 nodes, deg~40, 41 classes, F=128, 66% train
#   igb_sim       : 32768 nodes, deg~13, 19 classes, F=128, 60% train
#   products_sim  : 32768 nodes, deg~32, 47 classes, F=100,  8% train
#   papers_sim    : 65536 nodes, deg~15, 64 classes, F=128, 1.1% train
#                   (staged features: host-resident, UVA-style transfers)
# Fanouts are the DGL-style schedule [5, 10, 10] (input-most hop
# cheapest), 3 layers as in the paper.
# ---------------------------------------------------------------------------

MINI_SPECS: list[ModelSpec] = [
    ModelSpec("reddit_sim", "sage", num_nodes=16384, feat_dim=128,
              hidden_dim=64, num_classes=41),
    ModelSpec("igb_sim", "sage", num_nodes=32768, feat_dim=128,
              hidden_dim=64, num_classes=19),
    ModelSpec("products_sim", "sage", num_nodes=32768, feat_dim=100,
              hidden_dim=64, num_classes=47),
    ModelSpec("papers_sim", "sage", num_nodes=65536, feat_dim=128,
              hidden_dim=64, num_classes=64, feat_mode="staged"),
    # §6.4 other-model sweep (reddit stand-in)
    ModelSpec("reddit_sim_gcn", "gcn", num_nodes=16384, feat_dim=128,
              hidden_dim=64, num_classes=41),
    ModelSpec("reddit_sim_gat", "gat", num_nodes=16384, feat_dim=128,
              hidden_dim=64, num_classes=41, heads=2),
    # tiny artifact for rust integration tests / quickstart
    ModelSpec("tiny", "sage", num_nodes=2048, feat_dim=32, hidden_dim=32,
              num_classes=7, fanouts=(5, 5), batch_size=128),
    ModelSpec("tiny_gcn", "gcn", num_nodes=2048, feat_dim=32, hidden_dim=32,
              num_classes=7, fanouts=(5, 5), batch_size=128),
    ModelSpec("tiny_gat", "gat", num_nodes=2048, feat_dim=32, hidden_dim=32,
              num_classes=7, fanouts=(5, 5), batch_size=128, heads=2),
]

FULLBATCH_SPECS: list[FullBatchSpec] = [
    FullBatchSpec("reddit_sim_fb", num_nodes=16384, num_edges=720896,
                  feat_dim=128, hidden_dim=64, num_classes=41),
    FullBatchSpec("tiny_fb", num_nodes=2048, num_edges=32768, feat_dim=32,
                  hidden_dim=32, num_classes=7, layers=2, edge_chunk=8192),
]


def spec_by_name(name: str):
    for s in MINI_SPECS:
        if s.name == name:
            return s
    for s in FULLBATCH_SPECS:
        if s.name == name:
            return s
    raise KeyError(name)
