"""Layer-2: GNN forward/backward/Adam as pure JAX functions.

Each model (GraphSAGE / GCN / GAT) is expressed over the *padded
message-flow-graph* (MFG) batch layout the rust sampler produces:

* resident mode — the full feature table ``x_full [|V|, F]`` is a
  device-resident input (uploaded once by rust); layer-1 neighbor/self
  indices are **global node ids**.
* staged mode — rust gathers the batch's unique input frontier into
  ``x0 [cap0, F]`` per batch (the UVA-style path used for the
  papers100M stand-in); layer-1 indices are local rows of ``x0``.

For every layer ``l`` (1-based, ``caps[l]`` padded dst rows):

* ``idx_l  [caps[l], W] i32`` — neighbor slots into the previous layer's
  node array (W = fanout, +1 for GCN/GAT where slot 0 is the self loop).
* ``w_l    [caps[l], W] f32`` — aggregation weights with the validity
  mask folded in (SAGE: mask/deg; GCN: symmetric norm; GAT: 0/1 mask).
* ``self_l [caps[l]]    i32`` — self row (SAGE concat / GAT dst logits).

Padded rows point at row 0 with zero weight and are sliced away only at
the loss, where ``lmask`` zeroes padded roots.

The irregular aggregation is the Layer-1 Pallas kernel
(:mod:`compile.kernels.gather` / :mod:`compile.kernels.gat`); everything
dense (projections, loss, Adam) is plain jnp so XLA can fuse it.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels.gather import gather_rows, gather_wsum
from .kernels.gat import gat_aggregate
from .specs import FullBatchSpec, ModelSpec

ADAM_B1, ADAM_B2, ADAM_EPS = 0.9, 0.999, 1e-8


# ---------------------------------------------------------------------------
# Parameters
# ---------------------------------------------------------------------------

def param_shapes(spec: ModelSpec) -> list[tuple[str, tuple[int, ...]]]:
    """Flattened (name, shape) list; this order IS the artifact ABI."""
    out: list[tuple[str, tuple[int, ...]]] = []
    dims = spec.dims
    for l in range(spec.layers):
        din, dout = dims[l], dims[l + 1]
        if spec.model == "sage":
            out += [(f"w_self_{l}", (din, dout)),
                    (f"w_nbr_{l}", (din, dout)),
                    (f"b_{l}", (dout,))]
        elif spec.model == "gcn":
            out += [(f"w_{l}", (din, dout)), (f"b_{l}", (dout,))]
        elif spec.model == "gat":
            h = spec.heads
            # hidden layers concatenate heads, so layer l>0 consumes
            # heads * dims[l] features
            if l > 0:
                din = h * din
            out += [(f"w_{l}", (din, h * dout)),
                    (f"a_src_{l}", (h, dout)),
                    (f"a_dst_{l}", (h, dout)),
                    (f"b_{l}", (h * dout,))]
        else:
            raise ValueError(spec.model)
    return out


def fullbatch_param_shapes(spec: FullBatchSpec) -> list[tuple[str, tuple[int, ...]]]:
    out = []
    dims = spec.dims
    for l in range(spec.layers):
        out += [(f"w_{l}", (dims[l], dims[l + 1])), (f"b_{l}", (dims[l + 1],))]
    return out


# ---------------------------------------------------------------------------
# Batch input signature
# ---------------------------------------------------------------------------

def batch_inputs(spec: ModelSpec, with_labels: bool) -> list[tuple[str, tuple[int, ...], str]]:
    """(name, shape, dtype) of the per-batch data inputs, in ABI order."""
    caps = spec.node_caps
    ins: list[tuple[str, tuple[int, ...], str]] = []
    if spec.feat_mode == "resident":
        ins.append(("x_full", (spec.num_nodes, spec.feat_dim), "f32"))
    else:
        ins.append(("x0", (caps[0], spec.feat_dim), "f32"))
    for l in range(1, spec.layers + 1):
        n = caps[l]
        w = spec.idx_width(l)
        ins.append((f"idx_{l}", (n, w), "i32"))
        ins.append((f"w_{l}", (n, w), "f32"))
        if spec.model in ("sage", "gat"):
            ins.append((f"self_{l}", (n,), "i32"))
    if with_labels:
        b = caps[spec.layers]
        ins.append(("labels", (b,), "i32"))
        ins.append(("lmask", (b,), "f32"))
    return ins


# ---------------------------------------------------------------------------
# Forward passes
# ---------------------------------------------------------------------------

def _unpack_blocks(spec: ModelSpec, batch: list):
    """Split the flat batch-input list back into (x, blocks, rest)."""
    x = batch[0]
    blocks = []
    i = 1
    for _ in range(spec.layers):
        if spec.model in ("sage", "gat"):
            blocks.append((batch[i], batch[i + 1], batch[i + 2]))
            i += 3
        else:
            blocks.append((batch[i], batch[i + 1], None))
            i += 2
    return x, blocks, batch[i:]


def forward(spec: ModelSpec, params: list, batch: list):
    """Logits at the (padded) root nodes: ``[batch_cap, C]``."""
    x, blocks, _ = _unpack_blocks(spec, batch)
    h = x
    p = 0
    for l in range(spec.layers):
        idx, w, self_idx = blocks[l]
        last = l == spec.layers - 1
        if spec.model == "sage":
            w_self, w_nbr, b = params[p], params[p + 1], params[p + 2]
            p += 3
            h_nbr = gather_wsum(h, idx, w)
            h_self = gather_rows(h, self_idx)
            h = h_self @ w_self + h_nbr @ w_nbr + b
            if not last:
                h = jax.nn.relu(h)
        elif spec.model == "gcn":
            wmat, b = params[p], params[p + 1]
            p += 2
            h = gather_wsum(h, idx, w) @ wmat + b
            if not last:
                h = jax.nn.relu(h)
        else:  # gat
            wmat, a_src, a_dst, b = (params[p], params[p + 1],
                                     params[p + 2], params[p + 3])
            p += 4
            heads = spec.heads
            dout = a_src.shape[1]
            wh = h @ wmat  # [n_prev, H*dout] — dense, MXU-friendly
            whh = wh.reshape(-1, heads, dout)
            s_src = jnp.einsum("nhd,hd->nh", whh, a_src)
            s_dst_tab = jnp.einsum("nhd,hd->nh", whh, a_dst)
            s_dst = gather_rows(s_dst_tab, self_idx)
            h = gat_aggregate(wh, s_src, s_dst, idx, w, heads=heads) + b
            if last:
                # mean over heads -> class logits
                h = h.reshape(-1, heads, dout).mean(axis=1)
            else:
                h = jax.nn.elu(h)
    return h


def masked_loss(logits, labels, lmask):
    """Masked mean cross-entropy + masked correct count."""
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, labels[:, None], axis=1)[:, 0]
    denom = jnp.maximum(lmask.sum(), 1.0)
    loss = (nll * lmask).sum() / denom
    pred = jnp.argmax(logits, axis=-1)
    correct = ((pred == labels).astype(jnp.float32) * lmask).sum()
    return loss, correct


def adam_update(params, grads, m, v, t, lr, weight_decay):
    """torch-style Adam (weight decay folded into the gradient)."""
    new_p, new_m, new_v = [], [], []
    bc1 = 1.0 - ADAM_B1 ** t
    bc2 = 1.0 - ADAM_B2 ** t
    for p, g, mi, vi in zip(params, grads, m, v):
        g = g + weight_decay * p
        mi = ADAM_B1 * mi + (1.0 - ADAM_B1) * g
        vi = ADAM_B2 * vi + (1.0 - ADAM_B2) * g * g
        mhat = mi / bc1
        vhat = vi / bc2
        new_p.append(p - lr * mhat / (jnp.sqrt(vhat) + ADAM_EPS))
        new_m.append(mi)
        new_v.append(vi)
    return new_p, new_m, new_v


# ---------------------------------------------------------------------------
# Exported entry points (AOT-lowered by aot.py)
# ---------------------------------------------------------------------------

def make_train_step(spec: ModelSpec):
    """(params, m, v, t, lr, *batch, labels, lmask) -> (params', m', v',
    loss, correct)."""
    n_params = len(param_shapes(spec))

    def step(*args):
        params = list(args[:n_params])
        m = list(args[n_params:2 * n_params])
        v = list(args[2 * n_params:3 * n_params])
        t, lr = args[3 * n_params], args[3 * n_params + 1]
        batch = list(args[3 * n_params + 2:])
        labels, lmask = batch[-2], batch[-1]

        def loss_fn(ps):
            logits = forward(spec, ps, batch)
            loss, correct = masked_loss(logits, labels, lmask)
            return loss, correct

        (loss, correct), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params)
        new_p, new_m, new_v = adam_update(
            params, grads, m, v, t, lr, spec.weight_decay)
        return tuple(new_p) + tuple(new_m) + tuple(new_v) + (loss, correct)

    return step


def make_infer_step(spec: ModelSpec):
    """(params, *batch) -> logits [batch_cap, C]."""
    n_params = len(param_shapes(spec))

    def step(*args):
        params = list(args[:n_params])
        batch = list(args[n_params:])
        return (forward(spec, params, batch),)

    return step


# ---------------------------------------------------------------------------
# Full-batch GCN (comparison baseline, §2 / §3)
# ---------------------------------------------------------------------------

def _fullbatch_propagate(spec: FullBatchSpec, h, e_src, e_dst, e_w):
    """Chunked segment-sum A'h: scan over edge chunks to bound the
    materialized [chunk, H] gather."""
    n = spec.num_nodes
    chunks = spec.padded_edges // spec.edge_chunk
    src = e_src.reshape(chunks, spec.edge_chunk)
    dst = e_dst.reshape(chunks, spec.edge_chunk)
    ew = e_w.reshape(chunks, spec.edge_chunk)

    def body(acc, ch):
        s, d, w = ch
        msg = h[s] * w[:, None]
        acc = acc + jax.ops.segment_sum(msg, d, num_segments=n)
        return acc, None

    acc0 = jnp.zeros((n, h.shape[1]), jnp.float32)
    acc, _ = jax.lax.scan(body, acc0, (src, dst, ew))
    return acc


def fullbatch_forward(spec: FullBatchSpec, params, x, e_src, e_dst, e_w):
    h = x
    p = 0
    for l in range(spec.layers):
        w, b = params[p], params[p + 1]
        p += 2
        h = _fullbatch_propagate(spec, h, e_src, e_dst, e_w) @ w + b
        if l != spec.layers - 1:
            h = jax.nn.relu(h)
    return h


def make_fullbatch_train_step(spec: FullBatchSpec):
    """(params, m, v, t, lr, x, e_src, e_dst, e_w, labels, train_mask,
    val_mask) -> (params', m', v', loss, correct_train, correct_val)."""
    n_params = len(fullbatch_param_shapes(spec))

    def step(*args):
        params = list(args[:n_params])
        m = list(args[n_params:2 * n_params])
        v = list(args[2 * n_params:3 * n_params])
        t, lr = args[3 * n_params], args[3 * n_params + 1]
        x, e_src, e_dst, e_w, labels, tmask, vmask = args[3 * n_params + 2:]

        def loss_fn(ps):
            logits = fullbatch_forward(spec, ps, x, e_src, e_dst, e_w)
            loss, correct = masked_loss(logits, labels, tmask)
            _, correct_val = masked_loss(logits, labels, vmask)
            return loss, (correct, correct_val)

        (loss, (ct, cv)), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params)
        new_p, new_m, new_v = adam_update(
            params, grads, m, v, t, lr, spec.weight_decay)
        return tuple(new_p) + tuple(new_m) + tuple(new_v) + (loss, ct, cv)

    return step


def make_fullbatch_infer_step(spec: FullBatchSpec):
    """(params, x, e_src, e_dst, e_w) -> logits [N, C] (whole graph)."""
    n_params = len(fullbatch_param_shapes(spec))

    def step(*args):
        params = list(args[:n_params])
        x, e_src, e_dst, e_w = args[n_params:]
        return (fullbatch_forward(spec, params, x, e_src, e_dst, e_w),)

    return step
