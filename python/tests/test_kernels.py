"""Layer-1 correctness: Pallas kernels vs pure-jnp oracles.

Hypothesis sweeps shapes/seeds; the kernels must match `ref.py` to
float32 tolerance, including gradients (custom_vjp path).
"""

import numpy as np
import pytest
import jax
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile.kernels import gather, gat, ref

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")


def _rand(rng, *shape):
    return rng.standard_normal(shape).astype(np.float32)


# ---------------------------------------------------------------------------
# gather_wsum
# ---------------------------------------------------------------------------

@given(
    seed=st.integers(0, 2**31 - 1),
    n_in=st.integers(1, 300),
    blocks=st.integers(1, 4),
    fanout=st.integers(1, 12),
    feat=st.integers(1, 96),
)
def test_gather_wsum_matches_ref(seed, n_in, blocks, fanout, feat):
    block_rows = 32
    n_out = blocks * block_rows
    rng = np.random.default_rng(seed)
    src = _rand(rng, n_in, feat)
    idx = rng.integers(0, n_in, (n_out, fanout)).astype(np.int32)
    w = _rand(rng, n_out, fanout)
    out = gather.gather_wsum(src, idx, w, block_rows=block_rows)
    expect = ref.gather_wsum_ref(src, idx, w)
    np.testing.assert_allclose(out, expect, rtol=1e-5, atol=1e-5)


@given(seed=st.integers(0, 2**31 - 1))
def test_gather_wsum_zero_weights_give_zero(seed):
    rng = np.random.default_rng(seed)
    src = _rand(rng, 64, 16)
    idx = rng.integers(0, 64, (128, 5)).astype(np.int32)
    w = np.zeros((128, 5), np.float32)
    out = gather.gather_wsum(src, idx, w)
    assert np.all(np.asarray(out) == 0.0)


def test_gather_wsum_grads_match_ref():
    rng = np.random.default_rng(0)
    src = _rand(rng, 50, 24)
    idx = rng.integers(0, 50, (128, 7)).astype(np.int32)
    w = _rand(rng, 128, 7)

    def f_kernel(src, w):
        return jnp.sum(gather.gather_wsum(src, idx, w) ** 2)

    def f_ref(src, w):
        return jnp.sum(ref.gather_wsum_ref(src, idx, w) ** 2)

    g1 = jax.grad(f_kernel, argnums=(0, 1))(src, w)
    g2 = jax.grad(f_ref, argnums=(0, 1))(src, w)
    np.testing.assert_allclose(g1[0], g2[0], rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(g1[1], g2[1], rtol=1e-4, atol=1e-4)


def test_gather_rows():
    rng = np.random.default_rng(1)
    src = _rand(rng, 40, 8)
    idx = rng.integers(0, 40, (128,)).astype(np.int32)
    out = gather.gather_rows(src, idx)
    np.testing.assert_allclose(out, src[idx], rtol=1e-6)


def test_gather_wsum_rejects_misaligned_rows():
    rng = np.random.default_rng(2)
    src = _rand(rng, 16, 4)
    idx = rng.integers(0, 16, (100, 3)).astype(np.int32)  # not /128
    w = _rand(rng, 100, 3)
    with pytest.raises(AssertionError):
        gather._gather_wsum_pallas(src, idx, w)


# ---------------------------------------------------------------------------
# gat_aggregate
# ---------------------------------------------------------------------------

@given(
    seed=st.integers(0, 2**31 - 1),
    n_in=st.integers(2, 200),
    fanout=st.integers(1, 8),
    heads=st.sampled_from([1, 2, 4]),
    dh=st.integers(1, 16),
)
def test_gat_matches_ref(seed, n_in, fanout, heads, dh):
    n_out = 128
    rng = np.random.default_rng(seed)
    wh = _rand(rng, n_in, heads * dh)
    s_src = _rand(rng, n_in, heads)
    s_dst = _rand(rng, n_out, heads)
    idx = rng.integers(0, n_in, (n_out, fanout)).astype(np.int32)
    mask = (rng.random((n_out, fanout)) < 0.8).astype(np.float32)
    out = gat.gat_aggregate(wh, s_src, s_dst, idx, mask, heads=heads)
    expect = ref.gat_aggregate_ref(wh, s_src, s_dst, idx, mask, heads=heads)
    np.testing.assert_allclose(out, expect, rtol=2e-4, atol=2e-4)


def test_gat_fully_masked_rows_are_zero():
    rng = np.random.default_rng(3)
    wh = _rand(rng, 32, 8)
    s_src = _rand(rng, 32, 2)
    s_dst = _rand(rng, 128, 2)
    idx = rng.integers(0, 32, (128, 4)).astype(np.int32)
    mask = np.zeros((128, 4), np.float32)
    out = np.asarray(gat.gat_aggregate(wh, s_src, s_dst, idx, mask, heads=2))
    np.testing.assert_allclose(out, 0.0, atol=1e-6)


def test_gat_attention_is_convex_combination():
    # with all-ones mask the output lies in the convex hull of the
    # gathered rows (per head), so it is bounded by their min/max
    rng = np.random.default_rng(4)
    wh = _rand(rng, 64, 4)  # heads=1, dh=4
    s_src = _rand(rng, 64, 1)
    s_dst = _rand(rng, 128, 1)
    idx = rng.integers(0, 64, (128, 6)).astype(np.int32)
    mask = np.ones((128, 6), np.float32)
    out = np.asarray(gat.gat_aggregate(wh, s_src, s_dst, idx, mask, heads=1))
    g = wh[idx]  # [128, 6, 4]
    assert np.all(out <= g.max(axis=1) + 1e-4)
    assert np.all(out >= g.min(axis=1) - 1e-4)


def test_gat_grads_flow():
    rng = np.random.default_rng(5)
    wh = _rand(rng, 48, 6)
    s_src = _rand(rng, 48, 2)
    s_dst = _rand(rng, 128, 2)
    idx = rng.integers(0, 48, (128, 5)).astype(np.int32)
    mask = np.ones((128, 5), np.float32)

    def f(wh, s_src, s_dst):
        return jnp.sum(
            gat.gat_aggregate(wh, s_src, s_dst, idx, mask, heads=2) ** 2)

    def f_ref(wh, s_src, s_dst):
        return jnp.sum(
            ref.gat_aggregate_ref(wh, s_src, s_dst, idx, mask, heads=2) ** 2)

    g1 = jax.grad(f, argnums=(0, 1, 2))(wh, s_src, s_dst)
    g2 = jax.grad(f_ref, argnums=(0, 1, 2))(wh, s_src, s_dst)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(a, b, rtol=1e-3, atol=1e-3)


# ---------------------------------------------------------------------------
# additional structural properties
# ---------------------------------------------------------------------------

@given(
    seed=st.integers(0, 2**31 - 1),
    block_rows=st.sampled_from([16, 32, 64, 128]),
)
def test_gather_wsum_block_rows_invariance(seed, block_rows):
    """The output must not depend on the VMEM blocking choice."""
    rng = np.random.default_rng(seed)
    n_out = 256
    src = _rand(rng, 40, 12)
    idx = rng.integers(0, 40, (n_out, 4)).astype(np.int32)
    w = _rand(rng, n_out, 4)
    a = gather.gather_wsum(src, idx, w, block_rows=block_rows)
    b = ref.gather_wsum_ref(src, idx, w)
    np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-5)


def test_gather_wsum_linearity():
    """gather_wsum is linear in w: f(a*w1 + b*w2) = a*f(w1) + b*f(w2)."""
    rng = np.random.default_rng(6)
    src = _rand(rng, 30, 10)
    idx = rng.integers(0, 30, (128, 5)).astype(np.int32)
    w1 = _rand(rng, 128, 5)
    w2 = _rand(rng, 128, 5)
    lhs = gather.gather_wsum(src, idx, 2.0 * w1 + 3.0 * w2)
    rhs = 2.0 * gather.gather_wsum(src, idx, w1) + \
        3.0 * gather.gather_wsum(src, idx, w2)
    np.testing.assert_allclose(lhs, rhs, rtol=1e-4, atol=1e-4)


def test_gather_wsum_mean_of_identical_rows_is_row():
    """Mean-aggregating K copies of one row returns that row exactly."""
    rng = np.random.default_rng(7)
    src = _rand(rng, 20, 8)
    idx = np.full((128, 5), 7, np.int32)
    w = np.full((128, 5), 0.2, np.float32)
    out = np.asarray(gather.gather_wsum(src, idx, w))
    np.testing.assert_allclose(out, np.tile(src[7], (128, 1)), rtol=1e-5)


def test_gat_softmax_shift_invariance():
    """Adding a constant to all attention logits must not change the
    output (softmax shift invariance through the kernel)."""
    rng = np.random.default_rng(8)
    wh = _rand(rng, 32, 6)
    s_src = _rand(rng, 32, 2)
    s_dst = _rand(rng, 128, 2)
    idx = rng.integers(0, 32, (128, 4)).astype(np.int32)
    mask = np.ones((128, 4), np.float32)
    # shifting s_dst shifts every e[i,k,h] for row i equally, but only
    # when all logits stay on the same side of the LeakyReLU kink; use
    # large positive logits so the activation is linear
    s_src = np.abs(s_src) + 5.0
    s_dst = np.abs(s_dst) + 5.0
    a = np.asarray(gat.gat_aggregate(wh, s_src, s_dst, idx, mask, heads=2))
    b = np.asarray(gat.gat_aggregate(wh, s_src, s_dst + 3.0, idx, mask,
                                     heads=2))
    np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-4)
