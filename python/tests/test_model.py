"""Layer-2 model tests: shapes, masking semantics, Adam dynamics, and
the train step's loss-decrease sanity check for all three models."""

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from compile import model as M
from compile.specs import FullBatchSpec, ModelSpec


def small_spec(model="sage", heads=1):
    return ModelSpec(
        name="unit", model=model, num_nodes=256, feat_dim=16, hidden_dim=8,
        num_classes=5, fanouts=(3, 3), batch_size=128, heads=heads)


def make_batch(spec, rng, labels=True):
    caps = spec.node_caps
    args = []
    args.append(jnp.array(rng.standard_normal(
        (spec.num_nodes, spec.feat_dim)), jnp.float32))
    for l in range(1, spec.layers + 1):
        n = caps[l]
        w = spec.idx_width(l)
        hi = spec.num_nodes if l == 1 else caps[l - 1]
        args.append(jnp.array(rng.integers(0, hi, (n, w)), jnp.int32))
        wm = (rng.random((n, w)) < 0.8).astype(np.float32)
        if spec.model == "sage":  # normalize like the rust builder
            s = wm.sum(1, keepdims=True)
            wm = np.where(s > 0, wm / np.maximum(s, 1), 0.0).astype(np.float32)
        args.append(jnp.array(wm))
        if spec.model in ("sage", "gat"):
            args.append(jnp.array(rng.integers(0, hi, (n,)), jnp.int32))
    if labels:
        b = caps[spec.layers]
        args.append(jnp.array(rng.integers(0, spec.num_classes, (b,)), jnp.int32))
        lmask = np.zeros(b, np.float32)
        lmask[: b // 2] = 1.0
        args.append(jnp.array(lmask))
    return args


def init_params(spec, rng):
    return [jnp.array(0.1 * rng.standard_normal(s), jnp.float32)
            for _, s in M.param_shapes(spec)]


@pytest.mark.parametrize("model,heads", [("sage", 1), ("gcn", 1), ("gat", 2)])
def test_forward_shapes(model, heads):
    spec = small_spec(model, heads)
    rng = np.random.default_rng(0)
    params = init_params(spec, rng)
    batch = make_batch(spec, rng, labels=False)
    logits = M.forward(spec, params, batch)
    assert logits.shape == (spec.node_caps[spec.layers], spec.num_classes)
    assert np.all(np.isfinite(np.asarray(logits)))


def test_masked_loss_ignores_padded_roots():
    logits = jnp.array(np.random.default_rng(1).standard_normal((8, 4)),
                       jnp.float32)
    labels = jnp.zeros(8, jnp.int32)
    m1 = jnp.array([1, 1, 1, 1, 0, 0, 0, 0], jnp.float32)
    loss1, _ = M.masked_loss(logits, labels, m1)
    # changing logits under the mask must not change the loss
    logits2 = logits.at[5].set(100.0)
    loss2, _ = M.masked_loss(logits2, labels, m1)
    assert np.allclose(loss1, loss2)


def test_masked_loss_correct_count():
    logits = jnp.array([[10.0, 0.0], [0.0, 10.0], [10.0, 0.0]], jnp.float32)
    labels = jnp.array([0, 1, 1], jnp.int32)
    lmask = jnp.array([1.0, 1.0, 1.0], jnp.float32)
    _, correct = M.masked_loss(logits, labels, lmask)
    assert int(correct) == 2


def test_adam_matches_torch_semantics():
    # single scalar parameter, known trajectory
    p = [jnp.array([1.0], jnp.float32)]
    g = [jnp.array([0.5], jnp.float32)]
    m = [jnp.zeros(1, jnp.float32)]
    v = [jnp.zeros(1, jnp.float32)]
    new_p, new_m, new_v = M.adam_update(p, g, m, v, t=1.0, lr=0.1,
                                        weight_decay=0.0)
    # t=1: mhat = g, vhat = g^2 -> step = lr * g/(|g|+eps) = lr
    assert np.allclose(np.asarray(new_p[0]), 1.0 - 0.1, atol=1e-5)
    assert np.allclose(np.asarray(new_m[0]), 0.05, atol=1e-7)
    assert np.allclose(np.asarray(new_v[0]), 0.00025, atol=1e-9)


def test_weight_decay_shrinks_params():
    p = [jnp.array([1.0], jnp.float32)]
    g = [jnp.array([0.0], jnp.float32)]
    m = [jnp.zeros(1, jnp.float32)]
    v = [jnp.zeros(1, jnp.float32)]
    new_p, _, _ = M.adam_update(p, g, m, v, t=1.0, lr=0.1, weight_decay=0.1)
    assert float(new_p[0][0]) < 1.0


@pytest.mark.parametrize("model,heads", [("sage", 1), ("gcn", 1), ("gat", 2)])
def test_train_step_decreases_loss(model, heads):
    spec = small_spec(model, heads)
    rng = np.random.default_rng(2)
    step = jax.jit(M.make_train_step(spec))
    params = init_params(spec, rng)
    n = len(params)
    m = [jnp.zeros_like(x) for x in params]
    v = [jnp.zeros_like(x) for x in params]
    batch = make_batch(spec, rng, labels=True)
    losses = []
    t = 0
    for _ in range(8):
        t += 1
        out = step(*params, *m, *v, jnp.float32(t), jnp.float32(1e-2), *batch)
        params = list(out[:n])
        m = list(out[n:2 * n])
        v = list(out[2 * n:3 * n])
        losses.append(float(out[3 * n]))
    assert losses[-1] < losses[0], losses


def test_fullbatch_forward_matches_dense_reference():
    spec = FullBatchSpec("fbunit", num_nodes=32, num_edges=128, feat_dim=8,
                         hidden_dim=4, num_classes=3, layers=2, edge_chunk=64)
    rng = np.random.default_rng(3)
    params = [jnp.array(0.1 * rng.standard_normal(s), jnp.float32)
              for _, s in M.fullbatch_param_shapes(spec)]
    x = jnp.array(rng.standard_normal((32, 8)), jnp.float32)
    e = spec.padded_edges
    src = rng.integers(0, 32, e).astype(np.int32)
    dst = rng.integers(0, 32, e).astype(np.int32)
    w = rng.random(e).astype(np.float32)
    w[64:] = 0.0  # padding
    out = M.fullbatch_forward(spec, params, x, jnp.array(src),
                              jnp.array(dst), jnp.array(w))
    # dense reference: A[dst, src] += w
    A = np.zeros((32, 32), np.float32)
    for s_, d_, w_ in zip(src[:64], dst[:64], w[:64]):
        A[d_, s_] += w_
    h = np.asarray(x)
    ps = [np.asarray(p) for p in params]
    h = np.maximum(A @ h @ ps[0] + ps[1], 0)
    h = A @ h @ ps[2] + ps[3]
    np.testing.assert_allclose(np.asarray(out), h, rtol=1e-3, atol=1e-3)
