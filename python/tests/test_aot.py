"""AOT path tests: signatures are consistent, HLO text is emitted and
parseable, manifest matches the lowered module."""

import json
import os
import tempfile

import pytest
import jax

from compile import aot, model as M
from compile.specs import MINI_SPECS, ModelSpec, spec_by_name


def test_signature_input_counts():
    spec = spec_by_name("tiny")
    ins, outs = aot._mini_signature(spec, "train")
    n_params = len(M.param_shapes(spec))
    n_batch = len(M.batch_inputs(spec, with_labels=True))
    assert len(ins) == 3 * n_params + 2 + n_batch
    assert len(outs) == 3 * n_params + 2

    ins_i, outs_i = aot._mini_signature(spec, "infer")
    assert len(ins_i) == n_params + len(M.batch_inputs(spec, False))
    assert outs_i[0]["name"] == "logits"


def test_caps_are_block_aligned_and_monotone():
    for spec in MINI_SPECS:
        caps = spec.node_caps
        assert len(caps) == spec.layers + 1
        for c in caps:
            assert c % 128 == 0
        for a, b in zip(caps, caps[1:]):
            assert a >= b, f"{spec.name}: caps not decreasing {caps}"
        assert caps[-1] >= spec.batch_size


def test_lower_tiny_emits_parseable_hlo(tmp_path):
    spec = ModelSpec("unit_aot", "sage", num_nodes=512, feat_dim=16,
                     hidden_dim=8, num_classes=4, fanouts=(3, 3),
                     batch_size=128)
    fn = M.make_train_step(spec)
    ins, outs = aot._mini_signature(spec, "train")
    hlo = aot.lower_artifact(fn, ins)
    assert "ENTRY" in hlo
    assert "%main" in hlo or "main" in hlo
    # parameter count must match the manifest
    assert hlo.count("parameter(") >= len(ins)


def test_build_subset_writes_manifest(tmp_path):
    out = str(tmp_path / "artifacts")
    aot.build_all(out, only="tiny", verbose=False)
    with open(os.path.join(out, "manifest.json")) as f:
        man = json.load(f)
    assert "tiny.train" in man["artifacts"]
    ent = man["artifacts"]["tiny.train"]
    assert os.path.exists(os.path.join(out, ent["file"]))
    assert ent["spec"]["fanouts"] == [5, 5]
    assert ent["spec"]["node_caps"][-1] == 128
    # every input has name/shape/dtype
    for io in ent["inputs"] + ent["outputs"]:
        assert set(io) >= {"name", "shape", "dtype"}
